//! Chip-level cost simulator: composes the mapper, the NoC scheduler,
//! the core step models and the memory front into per-sample time and
//! energy — the numbers behind paper Tables III & IV and Figs 22–25.
//!
//! Execution model per training sample (section III.F):
//!
//! 1. DMA streams the 8-bit input codes through the TSVs (IO energy) and
//!    the NoC broadcasts them to layer-0 cores.
//! 2. Forward: layers evaluate sequentially (data dependence); all cores
//!    of a layer fire in parallel; combiner stages (Fig 14) add a step.
//!    Inter-layer outputs (3-bit codes) cross the statically scheduled
//!    mesh.
//! 3. Backward: mirrored, with 8-bit error codes.
//! 4. Update: all layers pulse their crossbars in parallel (each layer's
//!    errors and inputs are latched locally by then), so update adds one
//!    step of time and per-core energy everywhere.
//!
//! Recognition runs step 1–2 only. DR apps sum their per-stage AE costs
//! (one training item passes every stage each iteration). The clustering
//! rows use the digital core's cycle model instead.

use crate::config::hwspec as hw;
use crate::config::{apps, AppKind, Network, SystemConfig};
use crate::cores::risc::ConfigWork;
use crate::cores::{ClusterCore, RiscCore, Step};
use crate::mapper::{self, place, place_at, LayerMap, StageMap};
use crate::memory::DmaEngine;
use crate::noc::switch::SwitchConfig;
use crate::noc::{Schedule, Transfer, Xy};
use crate::power::{self, neural_core, EnergyAccount};

/// One row of Table III / Table IV.
#[derive(Clone, Debug)]
pub struct CostRow {
    pub app: String,
    pub cores: usize,
    pub time_s: f64,
    pub compute_j: f64,
    pub io_j: f64,
    pub noc_j: f64,
    pub total_j: f64,
}

impl CostRow {
    fn from_account(app: &str, cores: usize, acc: &EnergyAccount) -> Self {
        CostRow {
            app: app.to_string(),
            cores,
            time_s: acc.time_s,
            compute_j: acc.breakdown.compute_j,
            io_j: acc.breakdown.io_j,
            noc_j: acc.breakdown.noc_j,
            total_j: acc.breakdown.total_j(),
        }
    }
}

/// Account one compute step over the subset of a layer's cores.
fn layer_step(acc: &mut EnergyAccount, layer: &LayerMap, combiner: bool,
              step: Step) {
    let cores = layer
        .slices
        .iter()
        .filter(|s| s.is_combiner == combiner)
        .count();
    if cores == 0 {
        return;
    }
    acc.compute_step(cores, step.time_s(), step.power_w());
    acc.compute_overlap(cores, step.time_s(), neural_core::CTRL_POWER_W);
}

/// Account a group of transfers as one statically scheduled NoC step.
///
/// Memory-port traffic is *overlapped* with compute: the DMA double-
/// buffers the 4 kB input buffer (paper section II), so sample delivery
/// and activation spills pipeline with the previous/next sample and cost
/// energy but no steady-state time. The DRAM read itself is paid once
/// per payload (the buffer multicasts on-chip); the per-consumer copies
/// pay link energy only. Inter-core transfers take scheduled mesh time —
/// the paper's "majority of time is spent transferring neuron outputs".
fn noc_step(acc: &mut EnergyAccount, transfers: &[Transfer],
            sys: &SystemConfig, dma: &DmaEngine) {
    if transfers.is_empty() {
        return;
    }
    let port = sys.memory_port();
    let mesh: Vec<Transfer> = transfers
        .iter()
        .filter(|t| t.src != port && t.dst != port)
        .cloned()
        .collect();
    if !mesh.is_empty() {
        let sched = Schedule::build(&mesh, sys.link_bits);
        debug_assert!(sched.validate().is_ok());
        acc.time_s += sched.time_s(sys.cycle_s());
        acc.breakdown.noc_j += sched.energy_j(power::noc::ENERGY_PER_BIT_HOP_J);
    }
    // Overlapped memory-port traffic: DRAM+TSV energy once per payload
    // (consumers share one fetch), link energy per hop for each copy.
    let io_bits = transfers
        .iter()
        .filter(|t| t.src == port || t.dst == port)
        .map(|t| t.bits)
        .max()
        .unwrap_or(0);
    if io_bits > 0 {
        acc.io_overlap(io_bits,
                       dma.dram_energy_per_bit_j + dma.tsv_energy_per_bit_j);
        for t in transfers.iter().filter(|t| t.src == port || t.dst == port) {
            let hops = crate::noc::hops(t.src, t.dst) as f64;
            acc.breakdown.noc_j +=
                t.bits as f64 * hops * power::noc::ENERGY_PER_BIT_HOP_J;
        }
    }
}

/// Transfers grouped by the layer whose *inputs* they carry.
fn transfers_into_layer<'a>(
    all: &'a [Transfer],
    coords: &[Vec<(usize, usize)>],
    layer: usize,
) -> Vec<Transfer> {
    all.iter()
        .filter(|t| coords[layer].contains(&t.dst) || (
            // spills out of the previous layer head for DRAM
            layer > 0 && coords[layer - 1].contains(&t.src)
                && !coords.iter().any(|c| c.contains(&t.dst))
        ))
        .cloned()
        .collect()
}

/// Per-sample cost of training one stage (one BP iteration).
fn stage_train_cost(stage: &StageMap, sys: &SystemConfig,
                    acc: &mut EnergyAccount) {
    let dma = DmaEngine::default();
    let placement = place(stage, sys);
    // forward: per layer, deliver inputs then evaluate
    for (li, layer) in stage.layers.iter().enumerate() {
        let ts = transfers_into_layer(
            &placement.fwd_transfers, &placement.coords, li);
        noc_step(acc, &ts, sys, &dma);
        layer_step(acc, layer, false, Step::Forward);
        if layer.row_splits > 1 {
            // combiner traffic is inside `ts` (same dst layer); combiner
            // evaluation is an extra crossbar step
            layer_step(acc, layer, true, Step::Forward);
        }
    }
    // backward: errors flow from the output layer towards layer 0
    for (li, layer) in stage.layers.iter().enumerate().rev() {
        if layer.row_splits > 1 {
            layer_step(acc, layer, true, Step::Backward);
        }
        layer_step(acc, layer, false, Step::Backward);
        let ts: Vec<Transfer> = placement
            .bwd_transfers
            .iter()
            .filter(|t| placement.coords[li].contains(&t.src))
            .cloned()
            .collect();
        noc_step(acc, &ts, sys, &dma);
    }
    // update: all layers pulse in parallel -> one step of time, energy
    // for every core
    let all_cores = stage.cores_used();
    acc.compute_step(all_cores, Step::Update.time_s(), Step::Update.power_w());
    acc.compute_overlap(all_cores, Step::Update.time_s(),
                        neural_core::CTRL_POWER_W);
}

/// Per-sample recognition cost of a stage (forward only).
fn stage_recog_cost(stage: &StageMap, sys: &SystemConfig,
                    acc: &mut EnergyAccount) {
    let dma = DmaEngine::default();
    let placement = place(stage, sys);
    for (li, layer) in stage.layers.iter().enumerate() {
        let ts = transfers_into_layer(
            &placement.fwd_transfers, &placement.coords, li);
        noc_step(acc, &ts, sys, &dma);
        layer_step(acc, layer, false, Step::Forward);
        if layer.row_splits > 1 {
            layer_step(acc, layer, true, Step::Forward);
        }
    }
}

/// Table III row: per-sample per-iteration training cost.
pub fn train_cost(net: &Network, sys: &SystemConfig) -> Result<CostRow, String> {
    let map = mapper::map_network(net, sys)?;
    let mut acc = EnergyAccount::new();
    match net.kind {
        AppKind::Classifier | AppKind::Autoencoder => {
            stage_train_cost(&map.stages[0], sys, &mut acc);
        }
        AppKind::DimReduction => {
            // one training item passes through every AE stage
            for stage in &map.stages {
                stage_train_cost(stage, sys, &mut acc);
            }
        }
        AppKind::Kmeans => unreachable!("k-means uses kmeans_cost"),
    }
    Ok(CostRow::from_account(net.name, map.cores_used(), &acc))
}

/// The serving-configuration mapping of `net`: recognition (and
/// serving) always runs the deployed forward network — for DR apps the
/// trained encoder stack — so the net is mapped as a plain
/// feed-forward classifier. The single home of that remap rule, shared
/// by [`recognition_cost`], [`reconfig_cost`] and the multi-tenant
/// scheduler's footprints (`crate::chip`), so the three cannot drift.
pub fn serving_map(net: &Network, sys: &SystemConfig)
    -> Result<mapper::NetworkMap, String> {
    let fwd_net = Network {
        name: net.name,
        layers: net.layers,
        kind: AppKind::Classifier,
        classes: net.classes,
    };
    mapper::map_network(&fwd_net, sys)
}

/// Table IV row: per-sample recognition cost (full forward pass).
pub fn recognition_cost(net: &Network, sys: &SystemConfig)
    -> Result<CostRow, String> {
    let map = serving_map(net, sys)?;
    let mut acc = EnergyAccount::new();
    stage_recog_cost(&map.stages[0], sys, &mut acc);
    Ok(CostRow::from_account(net.name, map.cores_used(), &acc))
}

/// Modeled energy (J) of answering `requests` single-sample recognition
/// requests of `net` on one chip: the per-sample Table IV recognition
/// energy ([`recognition_cost`]) times the request count. The cluster
/// router (`crate::cluster`) prices each chip's share of routed traffic
/// with this — per-chip accounting for the fleet falls out of the same
/// energy model the paper's per-chip claims rest on.
pub fn serving_energy_j(net: &Network, sys: &SystemConfig, requests: usize)
    -> Result<f64, String> {
    Ok(recognition_cost(net, sys)?.total_j * requests as f64)
}

/// Clustering-core cost rows (training = assignment + amortised centre
/// update over `epoch_samples`; recognition = one assignment).
pub fn kmeans_cost(app: &apps::App, sys: &SystemConfig, train: bool,
                   epoch_samples: usize) -> Result<CostRow, String> {
    let core = ClusterCore::configure(app.dims, app.clusters, sys.clock_hz)?;
    let dma = DmaEngine::default();
    let mut acc = EnergyAccount::new();
    // features arrive from the DR network on-chip; only the TSV-crossing
    // result writeback counts as IO (paper Table III kmeans rows)
    let bits = (app.dims * 8) as u64;
    acc.io_overlap(bits, dma.tsv_energy_per_bit_j);
    let t = core.cycles_per_sample() as f64 / core.clock_hz;
    let mut time = t;
    if train {
        time += core.epoch_end_cycles() as f64
            / core.clock_hz
            / epoch_samples.max(1) as f64;
    }
    acc.time_s += time;
    acc.breakdown.compute_j += core.energy_j(time);
    Ok(CostRow::from_account(app.name, 1, &acc))
}

/// Modeled cost of reconfiguring the chip to host one application's
/// serving (recognition) configuration — what the "reconfigurable" in
/// the paper's title costs when the chip switches workloads (section
/// II: the mesh is statically time-multiplexed and reprogrammed between
/// applications). Two phases compose the swap:
///
/// 1. **Switch images** — the RISC core compiles the app's static TDM
///    schedule ([`Schedule`], built from its [`place`]ment) into per-
///    router SRAM slot images ([`SwitchConfig`]) and writes them over
///    the config bus ([`RiscCore::config_time_s`]).
/// 2. **Conductance programming** — every mapped crossbar's weight
///    matrix is rewritten row by row, one update pulse per occupied row
///    ([`Step::Update`]); rows program sequentially because the single
///    RISC core drives the programming DACs.
///
/// The multi-tenant scheduler ([`crate::chip`]) charges this cost into
/// its report on every swap-in (it never sleeps for it — the
/// reconfiguration is modeled, not emulated).
#[derive(Clone, Debug)]
pub struct ReconfigCost {
    /// Peak simultaneous cores of the serving configuration.
    pub cores: usize,
    /// Routers whose SRAM images are rewritten (occupied mesh stops
    /// plus the memory port).
    pub routers: usize,
    /// Switch SRAM bits written across those routers.
    pub switch_bits: u64,
    /// Crossbar rows re-programmed (one update pulse each).
    pub weight_rows: u64,
    /// RISC configuration-phase time: switch images + descriptors (s).
    pub config_time_s: f64,
    /// Crossbar programming time: rows x update-pulse time (s).
    pub program_time_s: f64,
}

impl ReconfigCost {
    /// Total modeled reconfiguration time (s): switch-image writes plus
    /// conductance programming.
    pub fn total_s(&self) -> f64 {
        self.config_time_s + self.program_time_s
    }
}

/// Compute the [`ReconfigCost`] of deploying `net`'s serving
/// configuration ([`serving_map`]).
pub fn reconfig_cost(net: &Network, sys: &SystemConfig)
    -> Result<ReconfigCost, String> {
    let map = serving_map(net, sys)?;
    Ok(reconfig_cost_of(&map.stages[0], sys))
}

/// [`ReconfigCost`] of deploying an already-mapped serving stage — the
/// multi-tenant scheduler builds each app's [`serving_map`] once and
/// prices it here without re-mapping.
pub fn reconfig_cost_of(stage: &StageMap, sys: &SystemConfig)
    -> ReconfigCost {
    let placement = place(stage, sys);
    // The static TDM schedule of the forward traffic fixes how many
    // slot images every router needs.
    let sched = Schedule::build(&placement.fwd_transfers, sys.link_bits);
    let slots = sched.makespan_slots().max(1) as usize;
    let cores = stage.cores_used();
    let routers = cores + 1; // occupied stops + the memory port
    let switch_bits =
        (routers * SwitchConfig::with_slots(slots).config_bits()) as u64;
    let risc = RiscCore { clock_hz: sys.clock_hz };
    let work = ConfigWork {
        neural_cores: cores,
        routers,
        switch_bits: switch_bits as usize,
        dma_descriptors: 2, // input stream in, result stream out
    };
    let weight_rows: u64 = stage
        .layers
        .iter()
        .flat_map(|l| l.slices.iter())
        .map(|s| s.core.inputs as u64)
        .sum();
    ReconfigCost {
        cores,
        routers,
        switch_bits,
        weight_rows,
        config_time_s: risc.config_time_s(&work),
        program_time_s: weight_rows as f64 * Step::Update.time_s(),
    }
}

/// Modeled cost of running `net`'s forward pass as a layer pipeline
/// ([`mapper::plan_pipeline`]) — the timing twin of
/// `coordinator::pipeline`.
///
/// Per stage: the forward-only cost of its resident layer group
/// (compute steps + intra-stage combiner traffic, as
/// [`recognition_cost`] prices them). Per stage *boundary*: the
/// producing layer's final outputs (combiner outputs when it was
/// row-split) crossing the mesh to the next stage's layer-0 consumer
/// cores at their planned offsets — each consumer receives exactly its
/// row segment, scheduled over the statically time-multiplexed NoC
/// ([`Schedule`]) like every other transfer in the model.
#[derive(Clone, Debug)]
pub struct PipelineCost {
    pub app: String,
    /// Sum of per-stage core demands.
    pub cores: usize,
    /// True when every stage holds its core group simultaneously
    /// (non-resident pipelines time-share; see
    /// [`mapper::PipelinePlan::resident`]).
    pub resident: bool,
    /// Per-stage forward compute time (s), in stream order.
    pub stage_time_s: Vec<f64>,
    /// Per-boundary NoC transfer time (s); entry `i` prices the
    /// stage `i` → `i+1` activation hop.
    pub hop_time_s: Vec<f64>,
    /// NoC energy of all stage-boundary hops (J).
    pub hop_energy_j: f64,
}

impl PipelineCost {
    /// Steady-state pipeline interval (s): the slowest stage plus its
    /// outgoing hop — once the pipe is full, one sample completes per
    /// interval, so throughput = 1 / interval.
    pub fn interval_s(&self) -> f64 {
        (0..self.stage_time_s.len())
            .map(|s| {
                self.stage_time_s[s]
                    + self.hop_time_s.get(s).copied().unwrap_or(0.0)
            })
            .fold(0.0, f64::max)
    }

    /// Fill latency (s): one sample's end-to-end path through every
    /// stage and boundary hop.
    pub fn latency_s(&self) -> f64 {
        self.stage_time_s.iter().sum::<f64>()
            + self.hop_time_s.iter().sum::<f64>()
    }
}

/// Stage-boundary transfers: the producing layer's final outputs to the
/// consuming layer's non-combiner cores, each receiving its row
/// segment — the inter-layer rule of [`place_at`], applied across the
/// stage boundary. Zero-hop pairs (a non-resident stage wrapping onto
/// its producer's stops) are local handoffs, not mesh traffic.
fn boundary_transfers(
    prod: &LayerMap,
    prod_coords: &[Xy],
    cons: &LayerMap,
    cons_coords: &[Xy],
) -> Vec<Transfer> {
    let mut out = Vec::new();
    for (s, sl) in cons.slices.iter().enumerate() {
        if sl.is_combiner {
            continue;
        }
        let (seg_lo, seg_hi) =
            mapper::row_segment(cons.n_in, cons.row_splits, sl.row_split);
        for (ps, p) in prod.slices.iter().enumerate() {
            let is_final = if prod.row_splits > 1 {
                p.is_combiner
            } else {
                !p.is_combiner
            };
            if !is_final {
                continue;
            }
            let lo = p.neurons.0.max(seg_lo);
            let hi = p.neurons.1.min(seg_hi);
            if lo >= hi || prod_coords[ps] == cons_coords[s] {
                continue;
            }
            out.push(Transfer {
                src: prod_coords[ps],
                dst: cons_coords[s],
                bits: (hi - lo) as u64 * hw::OUT_BITS as u64,
            });
        }
    }
    out
}

/// Price `net`'s forward pass as a `stages`-deep layer pipeline (see
/// [`PipelineCost`]). `stages` is clamped to `1..=n_layers` exactly as
/// the execution plan clamps it.
pub fn pipeline_cost(net: &Network, sys: &SystemConfig, stages: usize)
    -> Result<PipelineCost, String> {
    let plan = mapper::plan_pipeline(net, sys, stages)?;
    let dma = DmaEngine::default();
    let placements: Vec<mapper::Placement> = plan
        .stages
        .iter()
        .map(|st| place_at(&st.map, sys, st.core_offset))
        .collect();
    let mut stage_time_s = Vec::with_capacity(plan.n_stages());
    for (st, placement) in plan.stages.iter().zip(&placements) {
        let mut acc = EnergyAccount::new();
        for (li, layer) in st.map.layers.iter().enumerate() {
            // A later stage's layer 0 is fed by the boundary hop, not
            // the memory port its standalone placement assumes.
            if st.stage == 0 || li > 0 {
                let ts = transfers_into_layer(
                    &placement.fwd_transfers, &placement.coords, li);
                noc_step(&mut acc, &ts, sys, &dma);
            }
            layer_step(&mut acc, layer, false, Step::Forward);
            if layer.row_splits > 1 {
                layer_step(&mut acc, layer, true, Step::Forward);
            }
        }
        stage_time_s.push(acc.time_s);
    }
    let mut hop_time_s = Vec::new();
    let mut hop_energy_j = 0.0;
    for w in plan.stages.windows(2) {
        let (prod_st, cons_st) = (&w[0], &w[1]);
        let prod = prod_st.map.layers.last().expect("stage owns layers");
        let prod_li = prod_st.map.layers.len() - 1;
        let cons = &cons_st.map.layers[0];
        let ts = boundary_transfers(
            prod,
            &placements[prod_st.stage].coords[prod_li],
            cons,
            &placements[cons_st.stage].coords[0],
        );
        if ts.is_empty() {
            hop_time_s.push(0.0);
            continue;
        }
        let sched = Schedule::build(&ts, sys.link_bits);
        debug_assert!(sched.validate().is_ok());
        hop_time_s.push(sched.time_s(sys.cycle_s()));
        hop_energy_j += sched.energy_j(power::noc::ENERGY_PER_BIT_HOP_J);
    }
    Ok(PipelineCost {
        app: net.name.to_string(),
        cores: plan.total_cores,
        resident: plan.resident,
        stage_time_s,
        hop_time_s,
        hop_energy_j,
    })
}

/// All Table III rows in paper order.
pub fn table3(sys: &SystemConfig) -> Vec<CostRow> {
    let mut rows = Vec::new();
    for name in ["mnist_class", "mnist_dr", "isolet_dr", "isolet_class", "kdd_ae"] {
        rows.push(train_cost(apps::network(name).unwrap(), sys).unwrap());
    }
    for a in apps::KMEANS_APPS {
        rows.push(kmeans_cost(a, sys, true, 1000).unwrap());
    }
    rows
}

/// All Table IV rows in paper order.
pub fn table4(sys: &SystemConfig) -> Vec<CostRow> {
    let mut rows = Vec::new();
    for name in ["mnist_class", "mnist_dr", "isolet_dr", "isolet_class", "kdd_ae"] {
        rows.push(recognition_cost(apps::network(name).unwrap(), sys).unwrap());
    }
    for a in apps::KMEANS_APPS {
        rows.push(kmeans_cost(a, sys, false, 1000).unwrap());
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    fn net(name: &str) -> &'static Network {
        apps::network(name).unwrap()
    }

    #[test]
    fn training_slower_and_hungrier_than_recognition() {
        for name in ["kdd_ae", "mnist_class", "isolet_class"] {
            let t = train_cost(net(name), &sys()).unwrap();
            let r = recognition_cost(net(name), &sys()).unwrap();
            assert!(t.time_s > r.time_s, "{name}");
            assert!(t.total_j > r.total_j, "{name}");
        }
    }

    #[test]
    fn table3_shape_matches_paper() {
        let rows = table3(&sys());
        let by = |n: &str| rows.iter().find(|r| r.app == n).unwrap().clone();
        let mnist = by("mnist_class");
        let isolet = by("isolet_class");
        let kdd = by("kdd_ae");
        let km = by("mnist_kmeans");
        // time ordering: kmeans << kdd < mnist < isolet-ish (paper: 0.42,
        // 4.15, 7.29, 8.86 us)
        assert!(km.time_s < kdd.time_s);
        assert!(kdd.time_s < mnist.time_s);
        assert!(mnist.time_s < 30e-6, "mnist {}", mnist.time_s);
        assert!(mnist.time_s > 1e-6);
        assert!(isolet.time_s > mnist.time_s);
        // energy: isolet > mnist >> kmeans (paper: 9.9e-7, 4.3e-7, 1e-9)
        assert!(isolet.total_j > mnist.total_j);
        assert!(mnist.total_j > 100.0 * km.total_j);
        // compute dominates IO for the big nets (paper's observation)
        assert!(mnist.compute_j > mnist.io_j);
        assert!(isolet.compute_j > isolet.io_j);
    }

    #[test]
    fn table4_shape_matches_paper() {
        let rows = table4(&sys());
        let by = |n: &str| rows.iter().find(|r| r.app == n).unwrap().clone();
        let mnist = by("mnist_class");
        let km = by("mnist_kmeans");
        // paper: 0.77 us for mnist recognition, 0.32 us kmeans
        assert!(mnist.time_s > 0.2e-6 && mnist.time_s < 5e-6,
                "mnist {}", mnist.time_s);
        assert!(km.time_s > 0.05e-6 && km.time_s < 1e-6, "km {}", km.time_s);
    }

    #[test]
    fn serving_energy_scales_with_requests() {
        let one = serving_energy_j(net("mnist_class"), &sys(), 1).unwrap();
        let many = serving_energy_j(net("mnist_class"), &sys(), 1000).unwrap();
        let per_sample = recognition_cost(net("mnist_class"), &sys())
            .unwrap()
            .total_j;
        assert_eq!(one, per_sample);
        assert!((many - 1000.0 * one).abs() < 1e-12 * many.max(1.0));
        assert_eq!(serving_energy_j(net("iris_ae"), &sys(), 0).unwrap(), 0.0);
    }

    #[test]
    fn dr_training_costs_more_than_classifier() {
        // paper: Mnist_AE 17.99 us vs Mnist_class 7.29 us
        let ae = train_cost(net("mnist_dr"), &sys()).unwrap();
        let cl = train_cost(net("mnist_class"), &sys()).unwrap();
        assert!(ae.time_s > 1.2 * cl.time_s,
                "ae {} cl {}", ae.time_s, cl.time_s);
    }

    #[test]
    fn reconfig_cost_tracks_app_size() {
        let kdd = reconfig_cost(net("kdd_ae"), &sys()).unwrap();
        let mnist = reconfig_cost(net("mnist_class"), &sys()).unwrap();
        // both phases cost something, and bigger apps cost more
        assert!(kdd.total_s() > 0.0);
        assert!(kdd.switch_bits > 0 && kdd.weight_rows > 0);
        assert!(mnist.cores > kdd.cores);
        assert!(mnist.switch_bits > kdd.switch_bits);
        assert!(mnist.weight_rows > kdd.weight_rows);
        assert!(mnist.total_s() > kdd.total_s());
        // conductance programming dominates the switch images for a
        // crossbar-heavy app (thousands of rows vs a few kB of SRAM)
        assert!(mnist.program_time_s > mnist.config_time_s);
        // a full-app swap stays well under a millisecond-scale budget
        // per phase pair — reconfiguration is cheap relative to epochs
        assert!(mnist.total_s() < 10e-3, "{}", mnist.total_s());
        // kdd rows: 42-row encoder + 16-row decoder crossbars
        assert_eq!(kdd.weight_rows, 42 + 16);
        assert_eq!(kdd.routers, kdd.cores + 1);
    }

    #[test]
    fn pipeline_cost_splits_the_forward_pass() {
        let s = sys();
        let m = net("mnist_class");
        let whole = recognition_cost(m, &s).unwrap();
        let pipe = pipeline_cost(m, &s, 4).unwrap();
        assert_eq!(pipe.stage_time_s.len(), 4);
        assert_eq!(pipe.hop_time_s.len(), 3);
        assert!(pipe.resident);
        assert!(pipe.hop_energy_j > 0.0);
        // steady state: one result per interval, and the interval (the
        // slowest stage + its hop) beats the whole-pass latency — the
        // throughput the pipeline buys
        assert!(pipe.interval_s() > 0.0);
        assert!(pipe.interval_s() < whole.time_s,
                "interval {} whole {}", pipe.interval_s(), whole.time_s);
        // but a single sample still pays every stage and hop
        assert!(pipe.latency_s() > pipe.interval_s());
        // the degenerate one-stage pipeline has no hops and runs the
        // whole forward pass per interval
        let one = pipeline_cost(m, &s, 1).unwrap();
        assert!(one.hop_time_s.is_empty());
        assert_eq!(one.hop_energy_j, 0.0);
        assert!(one.interval_s() > pipe.interval_s());
        // non-resident pipelines still price (time-shared core groups)
        let iso = net("isolet_class");
        let deep = pipeline_cost(iso, &s, iso.layers.len() - 1).unwrap();
        assert!(!deep.resident);
        assert!(deep.latency_s() > 0.0);
    }

    #[test]
    fn kmeans_training_adds_epoch_end_cost() {
        let a = apps::kmeans_app("mnist_kmeans").unwrap();
        let tr = kmeans_cost(a, &sys(), true, 1000).unwrap();
        let re = kmeans_cost(a, &sys(), false, 1000).unwrap();
        assert!(tr.time_s > re.time_s);
    }
}
