//! One serving surface: the [`Service`] trait every serving front end
//! implements — the single-app [`Server`], the multi-tenant
//! [`ChipScheduler`](crate::chip::ChipScheduler), and the multi-chip
//! [`Cluster`](crate::cluster::Cluster).
//!
//! Before this trait the three fronts exposed three near-duplicate
//! submit/report shapes ([`ServeReport`] vs
//! [`MultiServeReport`](crate::chip::MultiServeReport) share their
//! accumulator but had no common interface). Clients, determinism
//! tests, and benches now drive *any* front through the same four
//! calls: [`Service::apps`], [`Service::submit`] (or the closed-loop
//! [`Service::call`]), [`Service::stats`], [`Service::shutdown`].
//!
//! The detailed per-front reports (latency percentiles, residency,
//! per-chip placement) remain available through each front's inherent
//! `shutdown` — the trait's [`ServeStats`] is the honest common
//! denominator: exact percentiles cannot be merged across apps or
//! chips, so the interface-level summary carries counts and wall time
//! only.

use anyhow::Result;

use super::{Pending, Response, Server};

/// Interface-level serving counters: the summary every [`Service`]
/// implementation can answer exactly, regardless of how many apps or
/// chips sit behind it. (Latency percentiles deliberately stay out:
/// they do not merge exactly across dispatch streams — read them from
/// the per-front reports instead.)
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServeStats {
    /// Apps this front serves.
    pub apps: usize,
    /// Requests answered (successes plus errors). Before shutdown this
    /// counts requests *accepted* so far (see [`Service::stats`]).
    pub requests: usize,
    /// Batches dispatched to an engine (0 until shutdown).
    pub batches: usize,
    /// Requests answered with an error (0 until shutdown).
    pub errors: usize,
    /// First dispatch → last completion, in seconds, across every
    /// dispatch stream behind the front (0 until shutdown).
    pub wall_s: f64,
}

impl ServeStats {
    /// Aggregate throughput in requests per second over
    /// [`Self::wall_s`] (0 before any request or when wall is unknown).
    pub fn throughput_rps(&self) -> f64 {
        if self.requests == 0 || self.wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{} app(s): {} requests in {} batches ({} errors) \
             over {:.3}s -> {:.0} req/s",
            self.apps,
            self.requests,
            self.batches,
            self.errors,
            self.wall_s,
            self.throughput_rps(),
        )
    }
}

/// The one serving surface (see the module docs). Implemented by
/// [`Server`], [`ChipScheduler`](crate::chip::ChipScheduler) and
/// [`Cluster`](crate::cluster::Cluster); write clients against
/// `&dyn Service` and they work on all three.
///
/// # Determinism contract
///
/// Every implementation answers a request bit-identically to a
/// dedicated single-app [`Server`] over the same `(network, params)` —
/// regardless of batching, co-residency, or which chip served it
/// (pinned by `rust/tests/serving_determinism.rs` and
/// `rust/tests/cluster_determinism.rs`).
pub trait Service: Send + Sync {
    /// Names of the apps this front serves, in registration order.
    fn apps(&self) -> Vec<String>;

    /// Enqueue one sample for `app` and return a [`Pending`] receipt;
    /// blocks while the app's bounded ingress queue is full, errors
    /// when `app` is not served or `x` has the wrong width.
    fn submit(&self, app: &str, x: Vec<f32>) -> Result<Pending>;

    /// Submit and block for the response — one closed-loop request.
    fn call(&self, app: &str, x: Vec<f32>) -> Result<Response> {
        self.submit(app, x)?.wait()
    }

    /// Live counters. Only request *acceptance* is observable while
    /// the dispatch streams run, so `requests` counts submissions so
    /// far and `batches`/`errors`/`wall_s` read 0; the post-shutdown
    /// numbers come from [`Service::shutdown`] or the front's inherent
    /// report.
    fn stats(&self) -> ServeStats;

    /// Drain outstanding requests, stop, and return the final
    /// counters. The detailed per-front report (latency splits,
    /// residency, placement) is available through the front's
    /// *inherent* `shutdown` instead.
    fn shutdown(self: Box<Self>) -> ServeStats;
}

impl Service for Server {
    fn apps(&self) -> Vec<String> {
        vec![self.app().to_string()]
    }

    fn submit(&self, app: &str, x: Vec<f32>) -> Result<Pending> {
        if app != self.app() {
            return Err(anyhow::anyhow!(
                "app {app:?} is not served here (serving {:?})",
                self.app()
            ));
        }
        self.client().submit(x)
    }

    fn stats(&self) -> ServeStats {
        ServeStats {
            apps: 1,
            requests: self.client().submitted(),
            ..ServeStats::default()
        }
    }

    fn shutdown(self: Box<Self>) -> ServeStats {
        Server::shutdown(*self).stats()
    }
}

#[cfg(test)]
mod tests {
    use super::super::ServeConfig;
    use super::*;
    use crate::config::apps;
    use crate::coordinator::{init_conductances, Engine};

    fn iris_service() -> Box<dyn Service> {
        let net = apps::network("iris_ae").unwrap().clone();
        let params = init_conductances(net.layers, 3);
        Box::new(Server::start(
            Engine::native(),
            net,
            params,
            ServeConfig::default(),
        ))
    }

    #[test]
    fn server_round_trips_through_the_trait() {
        let svc = iris_service();
        assert_eq!(svc.apps(), vec!["iris_ae".to_string()]);
        let out = svc.call("iris_ae", vec![0.1, 0.2, -0.1, 0.0]).unwrap();
        assert_eq!(out.out.len(), 4);
        let live = svc.stats();
        assert_eq!((live.apps, live.requests), (1, 1));
        assert_eq!(live.batches, 0, "batches are unknown before shutdown");
        let done = svc.shutdown();
        assert_eq!(done.requests, 1);
        assert_eq!(done.batches, 1);
        assert_eq!(done.errors, 0);
        assert!(done.wall_s >= 0.0);
    }

    #[test]
    fn unknown_app_is_rejected() {
        let svc = iris_service();
        let err = svc.submit("mnist_class", vec![0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("not served here"), "{err}");
        assert_eq!(svc.shutdown().requests, 0);
    }

    #[test]
    fn stats_ratios_and_summary() {
        let s = ServeStats::default();
        assert_eq!(s.throughput_rps(), 0.0);
        let s = ServeStats {
            apps: 2,
            requests: 12,
            batches: 4,
            errors: 1,
            wall_s: 2.0,
        };
        assert_eq!(s.throughput_rps(), 6.0);
        assert!(s.summary().contains("12 requests in 4 batches"));
    }
}
