//! Dynamic micro-batching over a bounded MPSC queue.
//!
//! [`Batcher`] is the coalescing core of the serving front end
//! (generic, so it is testable without an engine): it drains a
//! [`std::sync::mpsc`] receiver into batches that dispatch on **batch
//! full OR max-wait elapsed** — the classic dynamic-batching rule the
//! TPU serving stack popularised (Jouppi et al., arXiv:1704.04760,
//! §2: datacenter serving coalesces single-sample requests into
//! hardware-sized batches because the hardware only reaches peak
//! throughput at its native tile size).
//!
//! The wait only bounds *extra* waiting: items already sitting in the
//! queue are always taken greedily, so `max_wait = 0` still coalesces
//! whatever has piled up behind a slow dispatch — it just never stalls
//! a ready batch hoping for stragglers.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Coalesces items from a bounded MPSC receiver into dispatch-ready
/// batches of at most `max_batch` items (see the module docs for the
/// dispatch rule). Each drained item is paired with the [`Instant`] it
/// left the queue, so callers can split queue wait from batch wait in
/// their latency accounting.
///
/// ```
/// use std::sync::mpsc::sync_channel;
/// use std::time::Duration;
/// use restream::serve::Batcher;
///
/// let (tx, rx) = sync_channel(8);
/// for i in 0..5 {
///     tx.send(i).unwrap();
/// }
/// drop(tx); // producers gone: the batcher flushes what is queued
/// let batcher = Batcher::new(rx, 64, Duration::from_micros(200));
/// let batch = batcher.next_batch().unwrap();
/// assert_eq!(batch.len(), 5);
/// assert!(batcher.next_batch().is_none()); // queue closed and empty
/// ```
pub struct Batcher<T> {
    rx: Receiver<T>,
    max_batch: usize,
    max_wait: Duration,
}

impl<T> Batcher<T> {
    /// Wrap `rx` with a dispatch policy of at most `max_batch` items
    /// per batch (0 is treated as 1) and at most `max_wait` of waiting
    /// for stragglers after the first item of a batch arrives.
    pub fn new(rx: Receiver<T>, max_batch: usize, max_wait: Duration) -> Self {
        Batcher { rx, max_batch: max_batch.max(1), max_wait }
    }

    /// Largest batch a single dispatch may carry.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Longest a partially-filled batch waits for stragglers.
    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }

    /// Block for the next batch: `(item, dequeued-at)` pairs in arrival
    /// order, never empty, at most [`Self::max_batch`] long. Returns
    /// `None` once every sender has hung up and the queue is drained —
    /// the server's shutdown signal. A sender hanging up mid-batch
    /// flushes the partial batch rather than losing it.
    pub fn next_batch(&self) -> Option<Vec<(T, Instant)>> {
        let first = self.rx.recv().ok()?;
        let mut batch = vec![(first, Instant::now())];
        // Greedy phase: take whatever already queued up, without
        // waiting — this is what keeps `max_wait = 0` a pure
        // "no extra latency" policy that still batches under load.
        while batch.len() < self.max_batch {
            match self.rx.try_recv() {
                Ok(item) => batch.push((item, Instant::now())),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return Some(batch),
            }
        }
        // Waiting phase: block for stragglers until the deadline set
        // by the *first* item of the batch.
        let deadline = Instant::now() + self.max_wait;
        while batch.len() < self.max_batch {
            let Some(left) = deadline.checked_duration_since(Instant::now())
            else {
                break;
            };
            match self.rx.recv_timeout(left) {
                Ok(item) => batch.push((item, Instant::now())),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::thread;

    #[test]
    fn full_batches_dispatch_in_arrival_order() {
        let (tx, rx) = sync_channel(16);
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = Batcher::new(rx, 3, Duration::from_secs(1));
        let first: Vec<i32> =
            b.next_batch().unwrap().into_iter().map(|(v, _)| v).collect();
        let second: Vec<i32> =
            b.next_batch().unwrap().into_iter().map(|(v, _)| v).collect();
        assert_eq!(first, vec![0, 1, 2]);
        assert_eq!(second, vec![3, 4, 5]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_batch_one_is_sequential() {
        let (tx, rx) = sync_channel(8);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        drop(tx);
        // 0 clamps to 1; every item dispatches alone, no waiting.
        let b = Batcher::new(rx, 0, Duration::from_secs(1));
        assert_eq!(b.max_batch(), 1);
        for i in 0..4 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].0, i);
        }
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn zero_wait_still_coalesces_queued_items() {
        let (tx, rx) = sync_channel(8);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, 64, Duration::ZERO);
        // tx is still alive, so only the greedy phase may run — and it
        // must pick up everything already in the queue.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn waits_for_stragglers_within_deadline() {
        let (tx, rx) = sync_channel(8);
        let producer = thread::spawn(move || {
            tx.send(0).unwrap();
            thread::sleep(Duration::from_millis(10));
            tx.send(1).unwrap();
        });
        let b = Batcher::new(rx, 64, Duration::from_secs(5));
        // The second item lands well inside the generous deadline, and
        // the producer hang-up flushes the batch before max_wait.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(batch[1].1 >= batch[0].1, "dequeue times must be ordered");
        producer.join().unwrap();
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = sync_channel::<i32>(8);
        tx.send(7).unwrap();
        let b = Batcher::new(rx, 64, Duration::from_millis(5));
        // tx stays alive: only the deadline can end this batch.
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "deadline must flush long before a recv() would"
        );
        drop(tx);
    }
}
