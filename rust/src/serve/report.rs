//! Serving metrics: per-request latency split and the aggregate
//! [`ServeReport`] a [`Server`](super::Server) returns at shutdown.
//!
//! Latencies are measured server-side and split along the request
//! lifecycle (DESIGN.md "Serving layer"): **queue** (bounded input
//! queue, the 4 kB-input-buffer twin) → **batch** (waiting inside a
//! forming micro-batch) → **compute** (the pooled batched forward).
//! All figures are microseconds. Order statistics of a finished run
//! come out of bounded [`crate::telemetry::Histogram`]s (exact
//! count/sum/min/max, bucket-interpolated p50/p99 via
//! [`crate::metrics::histogram_quantile`]) — a long-running serve
//! holds four fixed-size histograms per app instead of an unbounded
//! per-request `Vec<f64>`. [`LatencyStats::from_us`] keeps the exact
//! sorted-sample path for callers that hold their own samples.

use std::time::Instant;

use crate::metrics::{mean, percentile_sorted};
use crate::telemetry::{Histogram, HistogramSnapshot};

/// Where one request's latency went, in microseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RequestTiming {
    /// Enqueue → drained out of the bounded request queue.
    pub queue_us: f64,
    /// Drained → the micro-batch it joined was dispatched.
    pub batch_us: f64,
    /// Dispatch → the pooled batched forward finished.
    pub compute_us: f64,
}

impl RequestTiming {
    /// End-to-end server-side latency (µs): queue + batch + compute.
    pub fn total_us(&self) -> f64 {
        self.queue_us + self.batch_us + self.compute_us
    }
}

/// Order statistics of one latency sample, in microseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    /// Arithmetic mean.
    pub mean_us: f64,
    /// Median (50th percentile).
    pub p50_us: f64,
    /// 99th percentile — the tail the batching window trades against.
    pub p99_us: f64,
    /// Worst observed value.
    pub max_us: f64,
}

impl LatencyStats {
    /// Summarise a sample of microsecond latencies (all zeros when the
    /// sample is empty). Sorts once and reads every order statistic
    /// off the sorted copy.
    pub fn from_us(values: &[f64]) -> LatencyStats {
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        LatencyStats {
            mean_us: mean(&sorted),
            p50_us: percentile_sorted(&sorted, 50.0),
            p99_us: percentile_sorted(&sorted, 99.0),
            max_us: sorted.last().copied().unwrap_or(0.0),
        }
    }

    /// Summarise a bounded histogram: mean and max are exact, p50/p99
    /// are bucket-interpolated (exact for single-sample series,
    /// clamped to the observed range, monotone p50 ≤ p99 ≤ max).
    pub fn from_histogram(h: &HistogramSnapshot) -> LatencyStats {
        LatencyStats {
            mean_us: h.mean(),
            p50_us: h.quantile(50.0),
            p99_us: h.quantile(99.0),
            max_us: h.max,
        }
    }

    /// Serialise as `{mean_us, p50_us, p99_us, max_us}`.
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        Json::obj()
            .with("mean_us", Json::Num(self.mean_us))
            .with("p50_us", Json::Num(self.p50_us))
            .with("p99_us", Json::Num(self.p99_us))
            .with("max_us", Json::Num(self.max_us))
    }
}

/// Running accumulation of one dispatch stream's timings — the mutable
/// state behind a [`ServeReport`]. The single-app dispatcher
/// (`serve_loop`) keeps one; the multi-tenant chip scheduler
/// (`crate::chip`) keeps one **per resident app**, which is what makes
/// per-app latency splits fall out of shared dispatch for free. (Not
/// to be confused with the public [`ServeStats`](super::ServeStats)
/// summary every [`Service`](super::Service) implementation answers.)
/// Memory is bounded: each latency phase accumulates into a
/// fixed-bucket [`Histogram`] (exact count/sum/min/max), so a serve
/// that answers millions of requests holds four histograms here, not
/// four million-entry `Vec`s.
#[derive(Debug, Default)]
pub(crate) struct StatsAccum {
    queue_us: Histogram,
    batch_us: Histogram,
    compute_us: Histogram,
    total_us: Histogram,
    batches: usize,
    errors: usize,
    /// First dispatch -> last completion.
    span: Option<(Instant, Instant)>,
}

impl StatsAccum {
    /// Note one dispatched batch (span bookkeeping + batch count).
    pub(crate) fn record_batch(&mut self, dispatch: Instant, done: Instant) {
        let start = self.span.map_or(dispatch, |(start, _)| start);
        self.span = Some((start, done));
        self.batches += 1;
    }

    /// Note one successfully answered request's latency split.
    pub(crate) fn record_timing(&mut self, timing: RequestTiming) {
        self.queue_us.observe(timing.queue_us);
        self.batch_us.observe(timing.batch_us);
        self.compute_us.observe(timing.compute_us);
        self.total_us.observe(timing.total_us());
    }

    /// Note `n` requests answered with an error.
    pub(crate) fn record_errors(&mut self, n: usize) {
        self.errors += n;
    }

    /// Freeze the accumulation into the aggregate [`ServeReport`].
    pub(crate) fn finish(&self) -> ServeReport {
        ServeReport {
            requests: self.total_us.count() as usize + self.errors,
            batches: self.batches,
            errors: self.errors,
            wall_s: self.span.map_or(0.0, |(start, end)| {
                end.saturating_duration_since(start).as_secs_f64()
            }),
            total: LatencyStats::from_histogram(&self.total_us.snapshot()),
            queue: LatencyStats::from_histogram(&self.queue_us.snapshot()),
            batch_wait: LatencyStats::from_histogram(
                &self.batch_us.snapshot(),
            ),
            compute: LatencyStats::from_histogram(
                &self.compute_us.snapshot(),
            ),
        }
    }
}

/// Aggregate statistics of one server lifetime, returned by
/// [`Server::shutdown`](super::Server::shutdown) and printed by
/// `restream serve` / the `perf_serving` bench.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests answered (successes plus errors).
    pub requests: usize,
    /// Batches dispatched to the engine.
    pub batches: usize,
    /// Requests answered with an error.
    pub errors: usize,
    /// First dispatch → last completion (s); the span
    /// [`Self::throughput_rps`] divides by.
    pub wall_s: f64,
    /// End-to-end latency (queue + batch + compute).
    pub total: LatencyStats,
    /// Time spent in the bounded request queue.
    pub queue: LatencyStats,
    /// Time spent waiting inside a forming micro-batch.
    pub batch_wait: LatencyStats,
    /// Time spent in the pooled batched forward.
    pub compute: LatencyStats,
}

impl ServeReport {
    /// Mean requests per dispatched batch (0 before any batch).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Aggregate throughput in requests per second over
    /// [`Self::wall_s`] (0 before any request).
    pub fn throughput_rps(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s.max(1e-12)
        }
    }

    /// Collapse into the interface-level [`ServeStats`](super::ServeStats)
    /// counters (one app: the server's own).
    pub fn stats(&self) -> super::ServeStats {
        super::ServeStats {
            apps: 1,
            requests: self.requests,
            batches: self.batches,
            errors: self.errors,
            wall_s: self.wall_s,
        }
    }

    /// Serialise under the shared report schema
    /// ([`crate::telemetry::REPORT_SCHEMA`], kind `"serve"`).
    pub fn to_json(&self) -> crate::telemetry::json::Json {
        use crate::telemetry::json::Json;
        Json::obj()
            .with(
                "schema",
                Json::Str(crate::telemetry::REPORT_SCHEMA.to_string()),
            )
            .with("kind", Json::Str("serve".to_string()))
            .with("requests", Json::Int(self.requests as i64))
            .with("batches", Json::Int(self.batches as i64))
            .with("errors", Json::Int(self.errors as i64))
            .with("wall_s", Json::Num(self.wall_s))
            .with("mean_batch", Json::Num(self.mean_batch()))
            .with("throughput_rps", Json::Num(self.throughput_rps()))
            .with("total", self.total.to_json())
            .with("queue", self.queue.to_json())
            .with("batch_wait", self.batch_wait.to_json())
            .with("compute", self.compute.to_json())
    }

    /// Human-readable multi-line summary (what `restream serve`
    /// prints after the request stream ends).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "served {} requests in {} batches (mean {:.1}/batch, \
             {} errors) over {:.3}s -> {:.0} req/s\n",
            self.requests,
            self.batches,
            self.mean_batch(),
            self.errors,
            self.wall_s,
            self.throughput_rps(),
        );
        s.push_str(&format!(
            "latency us: total  p50 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
            self.total.p50_us, self.total.p99_us, self.total.max_us,
        ));
        s.push_str(&format!(
            "            queue  p50 {:>8.1}  batch p50 {:>8.1}  \
             compute p50 {:>8.1}\n",
            self.queue.p50_us, self.batch_wait.p50_us, self.compute.p50_us,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total_is_the_sum_of_phases() {
        let t = RequestTiming { queue_us: 1.0, batch_us: 2.0, compute_us: 4.0 };
        assert_eq!(t.total_us(), 7.0);
    }

    #[test]
    fn latency_stats_order_correctly() {
        let values: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let s = LatencyStats::from_us(&values);
        assert_eq!(s.p50_us, 50.5);
        assert!((s.p99_us - 99.01).abs() < 1e-9, "p99 {}", s.p99_us);
        assert_eq!(s.max_us, 100.0);
        assert_eq!(s.mean_us, 50.5);
        let empty = LatencyStats::from_us(&[]);
        assert_eq!(empty.p50_us, 0.0);
        assert_eq!(empty.max_us, 0.0);
    }

    #[test]
    fn latency_stats_single_sample_is_total() {
        // A single-element sample must answer every percentile with the
        // element itself (the scheduler's per-app splits start at one
        // request; metrics::percentile is pinned the same way).
        let s = LatencyStats::from_us(&[42.0]);
        assert_eq!(s.p50_us, 42.0);
        assert_eq!(s.p99_us, 42.0);
        assert_eq!(s.max_us, 42.0);
        assert_eq!(s.mean_us, 42.0);
    }

    #[test]
    fn stats_accumulate_into_a_report() {
        let mut stats = StatsAccum::default();
        let t0 = Instant::now();
        stats.record_batch(t0, t0);
        stats.record_timing(RequestTiming {
            queue_us: 1.0,
            batch_us: 2.0,
            compute_us: 3.0,
        });
        stats.record_timing(RequestTiming {
            queue_us: 3.0,
            batch_us: 4.0,
            compute_us: 5.0,
        });
        stats.record_errors(1);
        let r = stats.finish();
        assert_eq!(r.requests, 3);
        assert_eq!(r.batches, 1);
        assert_eq!(r.errors, 1);
        assert_eq!(r.total.max_us, 12.0);
        assert_eq!(r.queue.mean_us, 2.0);
        // an untouched accumulator freezes into the empty report
        let empty = StatsAccum::default().finish();
        assert_eq!(empty.requests, 0);
        assert_eq!(empty.wall_s, 0.0);
    }

    #[test]
    fn report_serialises_and_reparses() {
        use crate::telemetry::json;
        let r = ServeReport {
            requests: 12,
            batches: 4,
            errors: 1,
            wall_s: 2.0,
            total: LatencyStats {
                mean_us: 5.0,
                p50_us: 4.0,
                p99_us: 9.0,
                max_us: 9.5,
            },
            ..Default::default()
        };
        let text = r.to_json().to_string();
        let doc = json::parse(&text).expect("valid json");
        assert_eq!(doc.to_string(), text);
        assert_eq!(
            doc.get("schema").and_then(json::Json::as_str),
            Some(crate::telemetry::REPORT_SCHEMA)
        );
        assert_eq!(
            doc.get("kind").and_then(json::Json::as_str),
            Some("serve")
        );
        assert_eq!(
            doc.get("requests").and_then(json::Json::as_i64),
            Some(12)
        );
        let p99 = doc
            .get("total")
            .and_then(|t| t.get("p99_us"))
            .and_then(json::Json::as_f64);
        assert_eq!(p99, Some(9.0));
    }

    #[test]
    fn report_ratios_guard_empty_runs() {
        let r = ServeReport::default();
        assert_eq!(r.mean_batch(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        let r = ServeReport {
            requests: 12,
            batches: 4,
            wall_s: 2.0,
            ..Default::default()
        };
        assert_eq!(r.mean_batch(), 3.0);
        assert_eq!(r.throughput_rps(), 6.0);
        assert!(r.summary().contains("12 requests in 4 batches"));
    }
}
