//! Serving metrics: per-request latency split and the aggregate
//! [`ServeReport`] a [`Server`](super::Server) returns at shutdown.
//!
//! Latencies are measured server-side and split along the request
//! lifecycle (DESIGN.md "Serving layer"): **queue** (bounded input
//! queue, the 4 kB-input-buffer twin) → **batch** (waiting inside a
//! forming micro-batch) → **compute** (the pooled batched forward).
//! All figures are microseconds; order statistics use
//! [`crate::metrics::percentile`].

use std::time::Instant;

use crate::metrics::{mean, percentile_sorted};

/// Where one request's latency went, in microseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RequestTiming {
    /// Enqueue → drained out of the bounded request queue.
    pub queue_us: f64,
    /// Drained → the micro-batch it joined was dispatched.
    pub batch_us: f64,
    /// Dispatch → the pooled batched forward finished.
    pub compute_us: f64,
}

impl RequestTiming {
    /// End-to-end server-side latency (µs): queue + batch + compute.
    pub fn total_us(&self) -> f64 {
        self.queue_us + self.batch_us + self.compute_us
    }
}

/// Order statistics of one latency sample, in microseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    /// Arithmetic mean.
    pub mean_us: f64,
    /// Median (50th percentile).
    pub p50_us: f64,
    /// 99th percentile — the tail the batching window trades against.
    pub p99_us: f64,
    /// Worst observed value.
    pub max_us: f64,
}

impl LatencyStats {
    /// Summarise a sample of microsecond latencies (all zeros when the
    /// sample is empty). Sorts once and reads every order statistic
    /// off the sorted copy.
    pub fn from_us(values: &[f64]) -> LatencyStats {
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        LatencyStats {
            mean_us: mean(&sorted),
            p50_us: percentile_sorted(&sorted, 50.0),
            p99_us: percentile_sorted(&sorted, 99.0),
            max_us: sorted.last().copied().unwrap_or(0.0),
        }
    }
}

/// Running accumulation of one dispatch stream's timings — the mutable
/// state behind a [`ServeReport`]. The single-app dispatcher
/// (`serve_loop`) keeps one; the multi-tenant chip scheduler
/// (`crate::chip`) keeps one **per resident app**, which is what makes
/// per-app latency splits fall out of shared dispatch for free. (Not
/// to be confused with the public [`ServeStats`](super::ServeStats)
/// summary every [`Service`](super::Service) implementation answers.)
#[derive(Debug, Default)]
pub(crate) struct StatsAccum {
    queue_us: Vec<f64>,
    batch_us: Vec<f64>,
    compute_us: Vec<f64>,
    total_us: Vec<f64>,
    batches: usize,
    errors: usize,
    /// First dispatch -> last completion.
    span: Option<(Instant, Instant)>,
}

impl StatsAccum {
    /// Note one dispatched batch (span bookkeeping + batch count).
    pub(crate) fn record_batch(&mut self, dispatch: Instant, done: Instant) {
        let start = self.span.map_or(dispatch, |(start, _)| start);
        self.span = Some((start, done));
        self.batches += 1;
    }

    /// Note one successfully answered request's latency split.
    pub(crate) fn record_timing(&mut self, timing: RequestTiming) {
        self.queue_us.push(timing.queue_us);
        self.batch_us.push(timing.batch_us);
        self.compute_us.push(timing.compute_us);
        self.total_us.push(timing.total_us());
    }

    /// Note `n` requests answered with an error.
    pub(crate) fn record_errors(&mut self, n: usize) {
        self.errors += n;
    }

    /// Freeze the accumulation into the aggregate [`ServeReport`].
    pub(crate) fn finish(&self) -> ServeReport {
        ServeReport {
            requests: self.total_us.len() + self.errors,
            batches: self.batches,
            errors: self.errors,
            wall_s: self.span.map_or(0.0, |(start, end)| {
                end.saturating_duration_since(start).as_secs_f64()
            }),
            total: LatencyStats::from_us(&self.total_us),
            queue: LatencyStats::from_us(&self.queue_us),
            batch_wait: LatencyStats::from_us(&self.batch_us),
            compute: LatencyStats::from_us(&self.compute_us),
        }
    }
}

/// Aggregate statistics of one server lifetime, returned by
/// [`Server::shutdown`](super::Server::shutdown) and printed by
/// `restream serve` / the `perf_serving` bench.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests answered (successes plus errors).
    pub requests: usize,
    /// Batches dispatched to the engine.
    pub batches: usize,
    /// Requests answered with an error.
    pub errors: usize,
    /// First dispatch → last completion (s); the span
    /// [`Self::throughput_rps`] divides by.
    pub wall_s: f64,
    /// End-to-end latency (queue + batch + compute).
    pub total: LatencyStats,
    /// Time spent in the bounded request queue.
    pub queue: LatencyStats,
    /// Time spent waiting inside a forming micro-batch.
    pub batch_wait: LatencyStats,
    /// Time spent in the pooled batched forward.
    pub compute: LatencyStats,
}

impl ServeReport {
    /// Mean requests per dispatched batch (0 before any batch).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Aggregate throughput in requests per second over
    /// [`Self::wall_s`] (0 before any request).
    pub fn throughput_rps(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s.max(1e-12)
        }
    }

    /// Collapse into the interface-level [`ServeStats`](super::ServeStats)
    /// counters (one app: the server's own).
    pub fn stats(&self) -> super::ServeStats {
        super::ServeStats {
            apps: 1,
            requests: self.requests,
            batches: self.batches,
            errors: self.errors,
            wall_s: self.wall_s,
        }
    }

    /// Human-readable multi-line summary (what `restream serve`
    /// prints after the request stream ends).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "served {} requests in {} batches (mean {:.1}/batch, \
             {} errors) over {:.3}s -> {:.0} req/s\n",
            self.requests,
            self.batches,
            self.mean_batch(),
            self.errors,
            self.wall_s,
            self.throughput_rps(),
        );
        s.push_str(&format!(
            "latency us: total  p50 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
            self.total.p50_us, self.total.p99_us, self.total.max_us,
        ));
        s.push_str(&format!(
            "            queue  p50 {:>8.1}  batch p50 {:>8.1}  \
             compute p50 {:>8.1}\n",
            self.queue.p50_us, self.batch_wait.p50_us, self.compute.p50_us,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_total_is_the_sum_of_phases() {
        let t = RequestTiming { queue_us: 1.0, batch_us: 2.0, compute_us: 4.0 };
        assert_eq!(t.total_us(), 7.0);
    }

    #[test]
    fn latency_stats_order_correctly() {
        let values: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let s = LatencyStats::from_us(&values);
        assert_eq!(s.p50_us, 50.5);
        assert!((s.p99_us - 99.01).abs() < 1e-9, "p99 {}", s.p99_us);
        assert_eq!(s.max_us, 100.0);
        assert_eq!(s.mean_us, 50.5);
        let empty = LatencyStats::from_us(&[]);
        assert_eq!(empty.p50_us, 0.0);
        assert_eq!(empty.max_us, 0.0);
    }

    #[test]
    fn latency_stats_single_sample_is_total() {
        // A single-element sample must answer every percentile with the
        // element itself (the scheduler's per-app splits start at one
        // request; metrics::percentile is pinned the same way).
        let s = LatencyStats::from_us(&[42.0]);
        assert_eq!(s.p50_us, 42.0);
        assert_eq!(s.p99_us, 42.0);
        assert_eq!(s.max_us, 42.0);
        assert_eq!(s.mean_us, 42.0);
    }

    #[test]
    fn stats_accumulate_into_a_report() {
        let mut stats = StatsAccum::default();
        let t0 = Instant::now();
        stats.record_batch(t0, t0);
        stats.record_timing(RequestTiming {
            queue_us: 1.0,
            batch_us: 2.0,
            compute_us: 3.0,
        });
        stats.record_timing(RequestTiming {
            queue_us: 3.0,
            batch_us: 4.0,
            compute_us: 5.0,
        });
        stats.record_errors(1);
        let r = stats.finish();
        assert_eq!(r.requests, 3);
        assert_eq!(r.batches, 1);
        assert_eq!(r.errors, 1);
        assert_eq!(r.total.max_us, 12.0);
        assert_eq!(r.queue.mean_us, 2.0);
        // an untouched accumulator freezes into the empty report
        let empty = StatsAccum::default().finish();
        assert_eq!(empty.requests, 0);
        assert_eq!(empty.wall_s, 0.0);
    }

    #[test]
    fn report_ratios_guard_empty_runs() {
        let r = ServeReport::default();
        assert_eq!(r.mean_batch(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        let r = ServeReport {
            requests: 12,
            batches: 4,
            wall_s: 2.0,
            ..Default::default()
        };
        assert_eq!(r.mean_batch(), 3.0);
        assert_eq!(r.throughput_rps(), 6.0);
        assert!(r.summary().contains("12 requests in 4 batches"));
    }
}
