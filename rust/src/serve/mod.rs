//! Serving front end: a dynamic micro-batching request server over the
//! [`Engine`]'s worker pool.
//!
//! The paper's chip is built for high-throughput streaming of
//! recognition traffic, but every batched [`Engine`] operation takes a
//! *pre-formed* batch — callers that hold single samples (a recognition
//! request per user, as in the follow-up streaming-multicore paper,
//! arXiv:1606.04609) would waste almost the whole 64-sample hardware
//! tile on padding. This module adds the missing request path:
//!
//! 1. **Bounded request queue** — [`Client::submit`] sends into a
//!    bounded MPSC channel sized from the chip's 4 kB input buffer
//!    ([`stream::buffer_capacity`]); a full queue blocks the submitter,
//!    the same backpressure the DMA sees when the input buffer fills.
//! 2. **Dynamic micro-batcher** — [`Batcher`] coalesces pending
//!    single-sample requests into batches of at most
//!    [`ServeConfig::max_batch`] (default [`apps::FWD_BATCH`], the
//!    64-sample tile) and dispatches on *batch full OR max-wait
//!    elapsed*.
//! 3. **Pooled execution** — each batch runs through [`Engine::infer`],
//!    i.e. the PR 2 sharded worker pool, inheriting its determinism
//!    contract.
//! 4. **Response routing** — each request's output row travels back
//!    over its own oneshot channel together with a [`RequestTiming`]
//!    latency split; aggregate statistics come out of
//!    [`Server::shutdown`] as a [`ServeReport`].
//!
//! With a [`crate::telemetry::Tracer`] in [`ServeConfig::trace`],
//! [`Client::submit`] additionally mints a trace id that rides the
//! request to the reply path, where one span per request (and one per
//! dispatched batch) is recorded — purely observational, so results
//! are bitwise-identical with tracing on or off
//! (`rust/tests/telemetry_determinism.rs` pins this).
//!
//! # Determinism contract
//!
//! A request's result is **bit-identical regardless of which batch it
//! lands in**. Batching changes only *where* a sample sits inside the
//! input matrix: the forward math is row-independent, tile padding is
//! zeros either way, and the sharded execution underneath is already
//! bit-identical at any worker count (see [`crate::coordinator::pool`]).
//! `rust/tests/serving_determinism.rs` pins this against single-sample
//! sequential evaluation across client counts and batch limits.
//!
//! # Example
//!
//! ```
//! use restream::config::apps;
//! use restream::coordinator::{init_conductances, Engine};
//! use restream::serve::{ServeConfig, Server};
//!
//! let net = apps::network("iris_ae").unwrap().clone();
//! let params = init_conductances(net.layers, 0);
//! let server =
//!     Server::start(Engine::native(), net, params, ServeConfig::default());
//! let response = server.client().call(vec![0.1, -0.2, 0.3, 0.0]).unwrap();
//! assert_eq!(response.out.len(), 4); // iris_ae reconstruction
//! let report = server.shutdown();
//! assert_eq!(report.requests, 1);
//! ```

// Rule P1's compiler-side shadow: the request path answers with typed
// errors, never panics. Tests keep their unwraps (the cfg_attr gate).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::dbg_macro))]

mod batcher;
mod report;
mod service;

pub use batcher::Batcher;
pub(crate) use report::StatsAccum;
pub use report::{LatencyStats, RequestTiming, ServeReport};
pub use service::{ServeStats, Service};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{apps, Network};
use crate::coordinator::{stream, Engine};
use crate::runtime::ArrayF32;
use crate::telemetry::{TraceSink, Tracer};

/// Tuning knobs of a [`Server`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Largest batch one dispatch may carry (0 is treated as 1;
    /// default [`apps::FWD_BATCH`] — the chip's 64-sample tile, past
    /// which a bigger batch only adds tiles, not efficiency).
    pub max_batch: usize,
    /// Longest a partially-filled batch waits for stragglers after its
    /// first request arrives (default 200 µs). Zero never waits but
    /// still coalesces whatever is already queued — see [`Batcher`].
    pub max_wait: Duration,
    /// Request-queue depth in samples. `None` (the default) sizes it
    /// from the chip's 4 kB input buffer via
    /// [`stream::buffer_capacity`] for the app's input width.
    pub queue_capacity: Option<usize>,
    /// Request tracer. `None` (the default) disables tracing — the
    /// reply path then records nothing and reads no clock.
    pub trace: Option<Arc<Tracer>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: apps::FWD_BATCH,
            max_wait: Duration::from_micros(200),
            queue_capacity: None,
            trace: None,
        }
    }
}

/// One request in flight: the sample plus the oneshot reply channel
/// (a rendezvous `sync_channel(1)` — the only message ever sent is the
/// response, so the send never blocks). Crate-visible so the
/// multi-tenant chip scheduler (`crate::chip`) dispatches the same
/// ingress type through [`answer_batch`].
pub(crate) struct Request {
    pub(crate) id: u64,
    pub(crate) x: Vec<f32>,
    pub(crate) enqueued: Instant,
    pub(crate) reply: SyncSender<Result<Response, String>>,
    /// Trace id minted at submit (`None` while tracing is off).
    pub(crate) trace_id: Option<u64>,
}

/// One served result.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id assigned at submission ([`Pending::id`]).
    pub id: u64,
    /// The network's output row for this request's sample — identical
    /// to what single-sample sequential [`Engine::infer`] returns.
    pub out: Vec<f32>,
    /// Server-side latency split for this request.
    pub timing: RequestTiming,
}

/// A submitted request's receipt; redeem with [`Pending::wait`].
pub struct Pending {
    id: u64,
    rx: Receiver<Result<Response, String>>,
    /// Opaque payload dropped when the receipt settles (waited on or
    /// abandoned) — the cluster router parks its in-flight token here
    /// so per-chip load decrements exactly when a request leaves.
    guard: Option<Box<dyn std::any::Any + Send>>,
    /// Trace id the request carries (`None` while tracing is off).
    trace_id: Option<u64>,
}

impl Pending {
    /// Id the server will answer under.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Trace id minted at submit, when tracing is on — lets the
    /// cluster router tag its routing events with the same id the
    /// request span will carry.
    pub fn trace_id(&self) -> Option<u64> {
        self.trace_id
    }

    /// Attach a drop-guard to this receipt (see the `guard` field).
    pub(crate) fn with_guard(
        mut self,
        guard: Box<dyn std::any::Any + Send>,
    ) -> Pending {
        self.guard = Some(guard);
        self
    }

    /// Block until the response arrives. Errors when the engine failed
    /// on this request's batch or the server shut down first.
    pub fn wait(self) -> Result<Response> {
        let _settled = self.guard;
        match self.rx.recv() {
            Ok(Ok(response)) => Ok(response),
            Ok(Err(msg)) => Err(anyhow!("request {}: {msg}", self.id)),
            Err(_) => Err(anyhow!(
                "request {}: server shut down before replying",
                self.id
            )),
        }
    }
}

/// Cheaply-cloneable handle for submitting requests to a [`Server`].
///
/// Every clone shares the server's bounded queue: when the queue is
/// full, [`Client::submit`] blocks until the batcher drains — the
/// input-buffer backpressure of the modeled DMA front. The server only
/// shuts down after **every** clone has been dropped.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    dims: usize,
    next_id: Arc<AtomicU64>,
    trace: Option<Arc<Tracer>>,
}

impl Client {
    /// Build a submission handle plus the receiving end of its bounded
    /// ingress queue (`capacity` samples deep, clamped to at least 1).
    /// [`Server::start`] builds one; the multi-tenant chip scheduler
    /// builds one **per hosted app**.
    pub(crate) fn channel(
        dims: usize,
        capacity: usize,
    ) -> (Client, Receiver<Request>) {
        Client::channel_traced(dims, capacity, None)
    }

    /// [`Client::channel`] with a tracer: every submit then mints a
    /// trace id that rides the request to the reply path.
    pub(crate) fn channel_traced(
        dims: usize,
        capacity: usize,
        trace: Option<Arc<Tracer>>,
    ) -> (Client, Receiver<Request>) {
        let (tx, rx) = sync_channel(capacity.max(1));
        let client = Client {
            tx,
            dims,
            next_id: Arc::new(AtomicU64::new(0)),
            trace,
        };
        (client, rx)
    }

    /// Enqueue one sample (must be exactly [`Client::dims`] wide) and
    /// return a [`Pending`] receipt; blocks while the queue is full.
    pub fn submit(&self, x: Vec<f32>) -> Result<Pending> {
        if x.len() != self.dims {
            return Err(anyhow!(
                "request has {} dims, the served app wants {}",
                x.len(),
                self.dims
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let trace_id = self.trace.as_ref().map(|t| t.mint());
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request {
                id,
                x,
                enqueued: Instant::now(),
                reply,
                trace_id,
            })
            .map_err(|_| anyhow!("server is shut down"))?;
        Ok(Pending { id, rx, guard: None, trace_id })
    }

    /// Submit and block for the response — one closed-loop request.
    pub fn call(&self, x: Vec<f32>) -> Result<Response> {
        self.submit(x)?.wait()
    }

    /// Input width the served network expects.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Requests accepted so far across every clone of this handle —
    /// the only counter observable while the dispatcher still runs
    /// (feeds the live [`Service::stats`]).
    pub(crate) fn submitted(&self) -> usize {
        self.next_id.load(Ordering::Relaxed) as usize
    }
}

/// A running micro-batching server: one dispatcher thread that owns the
/// [`Engine`] and the served network, fed by any number of [`Client`]
/// clones. See the module docs for the pipeline and determinism
/// contract, and DESIGN.md "Serving layer" for the full lifecycle.
pub struct Server {
    app: String,
    client: Client,
    handle: thread::JoinHandle<ServeReport>,
}

impl Server {
    /// Spawn the dispatcher thread over `engine` (which the server now
    /// owns, worker pool included), serving `net`'s forward path with
    /// `params`. The request queue is bounded per
    /// [`ServeConfig::queue_capacity`].
    pub fn start(
        engine: Engine,
        net: Network,
        params: Vec<ArrayF32>,
        cfg: ServeConfig,
    ) -> Server {
        let dims = net.layers[0];
        let app = net.name.to_string();
        let capacity = cfg
            .queue_capacity
            .unwrap_or_else(|| stream::buffer_capacity(dims))
            .max(1);
        let sink = TraceSink::for_app(cfg.trace.clone(), &app);
        let (client, rx) =
            Client::channel_traced(dims, capacity, cfg.trace.clone());
        let batcher = Batcher::new(rx, cfg.max_batch, cfg.max_wait);
        let handle = thread::Builder::new()
            .name("restream-serve".to_string())
            .spawn(move || serve_loop(engine, net, params, batcher, sink))
            // lint: allow(P1) — thread spawn fails only on OS resource
            // exhaustion at server start, before any request exists to
            // answer with a typed error.
            .expect("spawning serve dispatcher thread");
        Server { app, client, handle }
    }

    /// Name of the served network (the one app [`Service::apps`]
    /// reports).
    pub fn app(&self) -> &str {
        &self.app
    }

    /// A new submission handle (any number may exist; all share the
    /// bounded queue).
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Stop accepting requests and return the aggregate [`ServeReport`].
    /// Blocks until every outstanding [`Client`] clone has been dropped
    /// and the final (possibly partial) batch has been answered.
    pub fn shutdown(self) -> ServeReport {
        let Server { app: _, client, handle } = self;
        drop(client);
        // lint: allow(P1) — a dispatcher panic is already a bug; the
        // only honest continuation of shutdown is to propagate it.
        handle.join().expect("serve dispatcher thread panicked")
    }
}

/// Microseconds from `from` to `to` (saturating at zero).
fn us_between(from: Instant, to: Instant) -> f64 {
    to.saturating_duration_since(from).as_secs_f64() * 1e6
}

/// Move the owned samples out of a drained batch for dispatch. The
/// samples are never needed again after dispatch: moving instead of
/// cloning saves 64×784 floats per full MNIST tile on every batch.
pub(crate) fn take_batch_inputs(
    batch: &mut [(Request, Instant)],
) -> Vec<Vec<f32>> {
    batch
        .iter_mut()
        .map(|(request, _)| std::mem::take(&mut request.x))
        .collect()
}

/// Route one dispatched batch's outcome back over the per-request reply
/// channels and fold its timings into `stats`. Shared by the single-app
/// dispatcher ([`serve_loop`]) and the multi-tenant chip scheduler
/// (`crate::chip`), so the two cannot drift in batching math or latency
/// accounting.
pub(crate) fn answer_batch(
    result: Result<Vec<Vec<f32>>>,
    batch: Vec<(Request, Instant)>,
    dispatch: Instant,
    done: Instant,
    stats: &mut StatsAccum,
    sink: &TraceSink,
) {
    stats.record_batch(dispatch, done);
    sink.batch(batch.len(), us_between(dispatch, done));
    match result {
        Ok(rows) => {
            for ((request, dequeued), out) in batch.into_iter().zip(rows) {
                let timing = RequestTiming {
                    queue_us: us_between(request.enqueued, dequeued),
                    batch_us: us_between(dequeued, dispatch),
                    compute_us: us_between(dispatch, done),
                };
                stats.record_timing(timing);
                sink.request(
                    request.trace_id,
                    timing.queue_us,
                    timing.batch_us,
                    timing.compute_us,
                );
                let _ = request.reply.send(Ok(Response {
                    id: request.id,
                    out,
                    timing,
                }));
            }
        }
        Err(e) => {
            // The whole batch shares the engine failure; each
            // requester gets the message over its own channel.
            stats.record_errors(batch.len());
            let msg = format!("{e:#}");
            for (request, _) in batch {
                let _ = request.reply.send(Err(msg.clone()));
            }
        }
    }
}

/// The dispatcher: drain batches from the queue, run each through the
/// pooled batched forward, route rows back over the per-request reply
/// channels, and account latency/throughput. Runs until every client
/// hangs up.
fn serve_loop(
    engine: Engine,
    net: Network,
    params: Vec<ArrayF32>,
    batcher: Batcher<Request>,
    sink: TraceSink,
) -> ServeReport {
    let mut stats = StatsAccum::default();
    while let Some(mut batch) = batcher.next_batch() {
        let dispatch = Instant::now();
        let xs = take_batch_inputs(&mut batch);
        let result = engine.infer(&net, &params, &xs);
        let done = Instant::now();
        answer_batch(result, batch, dispatch, done, &mut stats, &sink);
    }
    stats.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::init_conductances;

    fn iris_server(cfg: ServeConfig) -> Server {
        let net = apps::network("iris_ae").unwrap().clone();
        let params = init_conductances(net.layers, 3);
        Server::start(Engine::native(), net, params, cfg)
    }

    #[test]
    fn call_round_trips_and_reports_timing() {
        let server = iris_server(ServeConfig::default());
        let client = server.client();
        assert_eq!(client.dims(), 4);
        let response = client.call(vec![0.1, 0.2, -0.1, 0.0]).unwrap();
        assert_eq!(response.out.len(), 4);
        assert!(response.timing.compute_us > 0.0);
        assert!(response.timing.total_us() >= response.timing.compute_us);
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.requests, 1);
        assert_eq!(report.batches, 1);
        assert_eq!(report.errors, 0);
        assert!(report.throughput_rps() > 0.0);
    }

    #[test]
    fn ragged_request_is_rejected_at_submit() {
        let server = iris_server(ServeConfig::default());
        let client = server.client();
        let err = client.submit(vec![0.0; 3]).unwrap_err();
        assert!(err.to_string().contains("3 dims"), "{err}");
        drop(client);
        assert_eq!(server.shutdown().requests, 0);
    }

    #[test]
    fn pending_requests_coalesce_into_batches() {
        // A generous window: all 8 requests from this thread land well
        // inside the first batch's wait, so far fewer than 8 batches
        // dispatch (normally exactly 1).
        let server = iris_server(ServeConfig {
            max_wait: Duration::from_millis(500),
            ..ServeConfig::default()
        });
        let client = server.client();
        let pendings: Vec<Pending> = (0..8)
            .map(|i| {
                client.submit(vec![i as f32 * 0.05, 0.1, -0.1, 0.2]).unwrap()
            })
            .collect();
        for (i, pending) in pendings.into_iter().enumerate() {
            assert_eq!(pending.id(), i as u64);
            assert_eq!(pending.wait().unwrap().id, i as u64);
        }
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.requests, 8);
        assert!(report.batches <= 2, "expected coalescing, got {report:?}");
        assert!(report.mean_batch() >= 4.0);
    }

    #[test]
    fn max_batch_one_serves_sequentially() {
        let server = iris_server(ServeConfig {
            max_batch: 1,
            ..ServeConfig::default()
        });
        let client = server.client();
        for _ in 0..5 {
            client.call(vec![0.3, -0.2, 0.1, 0.0]).unwrap();
        }
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.requests, 5);
        assert_eq!(report.batches, 5);
    }

    #[test]
    fn queue_capacity_defaults_to_input_buffer() {
        // The default queue depth follows the 4 kB input buffer: a
        // tiny explicit override must still serve correctly (depth 1
        // exercises full-queue backpressure on every submit).
        let server = iris_server(ServeConfig {
            queue_capacity: Some(1),
            ..ServeConfig::default()
        });
        let client = server.client();
        for _ in 0..10 {
            client.call(vec![0.1, 0.1, 0.1, 0.1]).unwrap();
        }
        drop(client);
        assert_eq!(server.shutdown().requests, 10);
    }

    #[test]
    fn zero_wait_with_unit_queue_serves_every_request() {
        // The tightest legal configuration: never wait for stragglers
        // and an ingress queue one sample deep, so every submit rides
        // the full-queue backpressure path. All requests must still be
        // answered, in order.
        let server = iris_server(ServeConfig {
            max_wait: Duration::ZERO,
            queue_capacity: Some(1),
            ..ServeConfig::default()
        });
        let client = server.client();
        let pendings: Vec<Pending> = (0..12)
            .map(|i| {
                client
                    .submit(vec![0.01 * i as f32, 0.0, 0.1, -0.1])
                    .unwrap()
            })
            .collect();
        for (i, pending) in pendings.into_iter().enumerate() {
            assert_eq!(pending.wait().unwrap().id, i as u64);
        }
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.requests, 12);
        assert_eq!(report.errors, 0);
    }

    #[test]
    fn empty_request_is_rejected_at_submit() {
        // A zero-dim sample must be refused before it reaches the
        // queue — same typed path as any other width mismatch.
        let server = iris_server(ServeConfig::default());
        let client = server.client();
        let err = client.submit(Vec::new()).unwrap_err();
        assert!(err.to_string().contains("0 dims"), "{err}");
        drop(client);
        assert_eq!(server.shutdown().requests, 0);
    }

    #[test]
    fn shutdown_answers_every_queued_request() {
        // Requests still queued when the last client hangs up must be
        // answered, never dropped: the batcher flushes its partial
        // batch on disconnect and shutdown joins the dispatcher. The
        // generous max_wait guarantees the burst is still queued when
        // shutdown starts.
        let server = iris_server(ServeConfig {
            max_wait: Duration::from_secs(5),
            ..ServeConfig::default()
        });
        let client = server.client();
        let pendings: Vec<Pending> = (0..7)
            .map(|_| client.submit(vec![0.2, -0.1, 0.0, 0.3]).unwrap())
            .collect();
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.requests, 7);
        assert_eq!(report.errors, 0);
        for pending in pendings {
            // replies were buffered before shutdown returned; the
            // typed "shut down before replying" error here would mean
            // a request was silently dropped
            pending.wait().expect("queued request was dropped");
        }
    }

    #[test]
    fn a_dead_dispatcher_is_a_typed_error_not_a_hang() {
        // Drop the receiving end with a request still queued: the
        // receipt settles with the typed shutdown error (anything
        // else would hang the caller forever), and later submits
        // fail fast with their own typed error.
        let (client, rx) = Client::channel(4, 4);
        let pending = client.submit(vec![0.0; 4]).unwrap();
        drop(rx);
        let err = pending.wait().unwrap_err();
        assert!(
            err.to_string().contains("shut down before replying"),
            "{err}"
        );
        let err = client.submit(vec![0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("server is shut down"), "{err}");
    }

    #[test]
    fn broken_params_surface_as_request_errors() {
        let net = apps::network("iris_ae").unwrap().clone();
        let mut params = init_conductances(net.layers, 3);
        // An odd parameter list cannot form (gp, gn) pairs; the engine
        // rejects the batch and every requester must hear about it.
        params.pop();
        let server = Server::start(
            Engine::native(),
            net,
            params,
            ServeConfig::default(),
        );
        let client = server.client();
        let err = client.call(vec![0.1, 0.2, 0.3, 0.4]).unwrap_err();
        assert!(err.to_string().starts_with("request 0"), "{err}");
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.errors, 1);
        assert_eq!(report.requests, 1);
    }
}
