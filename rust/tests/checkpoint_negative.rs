//! Checkpoint failure paths: every way a restore can go wrong must be
//! a **typed** [`CheckpointError`] — never a panic, never a partially
//! mutated engine. After any failed resume the same engine trains
//! normally and bit-identically to a fresh one.
//!
//! Deliberately exercises the deprecated `train_*` wrappers: these
//! tests pin that the thin wrappers still reach the shared internal
//! bodies behind `Engine::fit`.
#![allow(deprecated)]

use std::path::PathBuf;

use restream::checkpoint::{self, CheckpointError};
use restream::config::apps;
use restream::coordinator::{CheckpointOpts, Engine};
use restream::runtime::ArrayF32;
use restream::testing::Rng;

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "restream-ckpt-neg-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn rows(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

fn assert_params_eq(a: &[ArrayF32], b: &[ArrayF32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param count");
    for (l, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.data, y.data, "{what}: param {l}");
    }
}

/// Train iris_ae for 2 epochs with checkpointing into a fresh `tag`
/// directory; returns (dir, the checkpoint path, the dataset).
fn make_checkpoint(tag: &str) -> (PathBuf, PathBuf, Vec<Vec<f32>>) {
    let net = apps::network("iris_ae").unwrap();
    let mut rng = Rng::seeded(0xBAD ^ tag.len() as u64);
    let xs = rows(&mut rng, 8, net.layers[0]);
    let dir = scratch(tag);
    let xs2 = xs.clone();
    Engine::native()
        .train_checkpointed(net, &xs, move |i| xs2[i].clone(), 2, 0.5, 3,
                            1, &CheckpointOpts::new(&dir))
        .unwrap();
    let path = checkpoint::latest(&dir).unwrap().unwrap();
    (dir, path, xs)
}

#[test]
fn truncated_payload_is_a_typed_error() {
    let (dir, path, _) = make_checkpoint("trunc");
    let bytes = std::fs::read(path.join("state.bin")).unwrap();
    std::fs::write(path.join("state.bin"), &bytes[..bytes.len() - 9])
        .unwrap();
    match checkpoint::load(&path) {
        Err(CheckpointError::Truncated { needed, got, .. }) => {
            assert_eq!(needed, bytes.len() as u64);
            assert_eq!(got, bytes.len() as u64 - 9);
        }
        other => panic!("want Truncated, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_bit_is_a_checksum_mismatch_not_a_decode_attempt() {
    let (dir, path, _) = make_checkpoint("flip");
    let mut bytes = std::fs::read(path.join("params.bin")).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40; // same length, different content
    std::fs::write(path.join("params.bin"), &bytes).unwrap();
    assert!(matches!(
        checkpoint::load(&path),
        Err(CheckpointError::ChecksumMismatch { .. })
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_directory_is_missing_not_a_panic() {
    let dir = scratch("missing");
    assert!(matches!(
        Engine::native().resume_from(&dir),
        Err(CheckpointError::Missing { .. })
    ));
    // a directory that exists but holds no checkpoints is also Missing
    std::fs::create_dir_all(&dir).unwrap();
    assert!(matches!(
        Engine::native().resume_from(&dir),
        Err(CheckpointError::Missing { .. })
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn foreign_app_checkpoint_is_rejected_before_training() {
    // iris_ae checkpoint, iris_class resume: the typed mismatch must
    // surface through the anyhow boundary with its diagnosis intact,
    // and the engine must stay fully usable afterwards.
    let (dir, _, _) = make_checkpoint("foreign");
    let net = apps::network("iris_class").unwrap();
    let mut rng = Rng::seeded(0xF0E);
    let xs = rows(&mut rng, 8, net.layers[0]);
    let ts: Vec<Vec<f32>> =
        (0..8).map(|_| rng.vec_uniform(1, -0.4, 0.4)).collect();
    let engine = Engine::native();
    let mut opts = CheckpointOpts::new(&dir);
    opts.resume = true;
    let ts_a = ts.clone();
    let err = engine
        .train_checkpointed(net, &xs, move |i| ts_a[i].clone(), 2, 0.5,
                            3, 1, &opts)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("belongs to app 'iris_ae'"),
        "diagnosis lost: {msg}"
    );

    // no partial mutation: the failed resume did not train anything —
    // the same engine now trains bit-identically to a fresh one
    let ts_b = ts.clone();
    let (p_after, _) = engine
        .train_with(net, &xs, move |i| ts_b[i].clone(), 2, 0.5, 3, 1)
        .unwrap();
    let ts_c = ts.clone();
    let (p_fresh, _) = Engine::native()
        .train_with(net, &xs, move |i| ts_c[i].clone(), 2, 0.5, 3, 1)
        .unwrap();
    assert_params_eq(&p_fresh, &p_after, "engine after failed resume");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fingerprint_mismatch_is_rejected() {
    // Rewrite the checkpoint with a flipped hardware fingerprint (the
    // writer recomputes checksums, so only the fingerprint check can
    // object) — resuming must refuse with the typed error.
    let (dir, path, xs) = make_checkpoint("fprint");
    let mut state = checkpoint::load(&path).unwrap();
    state.fingerprint ^= 0xDEAD;
    checkpoint::save(&dir, &state).unwrap();

    let net = apps::network("iris_ae").unwrap();
    let mut opts = CheckpointOpts::new(&dir);
    opts.resume = true;
    let xs2 = xs.clone();
    let err = Engine::native()
        .train_checkpointed(net, &xs, move |i| xs2[i].clone(), 4, 0.5, 3,
                            1, &opts)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fingerprint mismatch"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hyperparameter_drift_is_rejected() {
    // A checkpoint can only continue the exact run it recorded: a
    // different seed, lr, batch or dataset size cannot replay the same
    // stream and must be refused, not silently diverge.
    let (dir, _, xs) = make_checkpoint("hyper");
    let net = apps::network("iris_ae").unwrap();
    let mut opts = CheckpointOpts::new(&dir);
    opts.resume = true;

    let cases: Vec<(&str, u64, f32, usize, usize)> = vec![
        ("seed", 4, 0.5, 1, 8),
        ("lr", 3, 0.25, 1, 8),
        ("batch", 3, 0.5, 2, 8),
        ("samples", 3, 0.5, 1, 6),
    ];
    for (what, seed, lr, batch, n) in cases {
        let xs_n: Vec<Vec<f32>> = xs[..n].to_vec();
        let xs2 = xs_n.clone();
        let err = Engine::native()
            .train_checkpointed(net, &xs_n, move |i| xs2[i].clone(), 4,
                                lr, seed, batch, &opts)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checkpoint"),
            "{what}: diagnosis lost: {msg}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn garbled_manifest_and_trailing_bytes_are_bad_format() {
    let (dir, path, _) = make_checkpoint("garble");
    // trailing garbage after a structurally valid payload
    let mut bytes = std::fs::read(path.join("state.bin")).unwrap();
    bytes.extend_from_slice(b"\0\0\0\0");
    std::fs::write(path.join("state.bin"), &bytes).unwrap();
    // keep the manifest consistent so the decoder (not the checksum)
    // is what objects
    let state_fnv = checkpoint::fnv64(&bytes);
    let manifest = std::fs::read_to_string(path.join("MANIFEST")).unwrap();
    let fixed: String = manifest
        .lines()
        .map(|l| {
            if l.starts_with("file state.bin") {
                format!("file state.bin {} {:016x}\n", bytes.len(),
                        state_fnv)
            } else {
                format!("{l}\n")
            }
        })
        .collect();
    std::fs::write(path.join("MANIFEST"), fixed).unwrap();
    match checkpoint::load(&path) {
        Err(CheckpointError::BadFormat { detail, .. }) => {
            assert!(detail.contains("trailing"), "{detail}");
        }
        other => panic!("want BadFormat, got {other:?}"),
    }

    // a manifest with a mangled header is BadFormat too
    std::fs::write(path.join("MANIFEST"), "restream-checkpoint v999\n")
        .unwrap();
    assert!(matches!(
        checkpoint::load(&path),
        Err(CheckpointError::BadFormat { .. })
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn staging_leftovers_are_never_resumed() {
    // A crash mid-commit leaves a `.tmp-…` staging dir; latest() must
    // skip it (and any ckpt dir without a manifest) rather than resume
    // a half-written snapshot.
    let (dir, path, _) = make_checkpoint("staging");
    let staged = dir.join(".tmp-ckpt-s000-e000099");
    std::fs::create_dir_all(&staged).unwrap();
    std::fs::write(staged.join("state.bin"), b"partial").unwrap();
    let manifestless = dir.join("ckpt-s000-e000098");
    std::fs::create_dir_all(&manifestless).unwrap();
    let latest = checkpoint::latest(&dir).unwrap().unwrap();
    assert_eq!(latest, path, "latest must be the last complete commit");
    let _ = std::fs::remove_dir_all(&dir);
}
