//! Telemetry determinism: tracing must be a pure *observer*.
//!
//! The observability layer (PR 10) records spans and metrics strictly
//! after compute completes and never feeds a recorded value back into
//! batching, dispatch, routing, or the kernels. This suite pins the
//! resulting contract:
//!
//! * **Bit-identity** — every served output is bitwise-identical with
//!   tracing on or off, across apps × exec modes × worker counts, and
//!   across all three serving granularities (dedicated server,
//!   multi-tenant chip, multi-chip cluster).
//! * **Completeness** — the chrome `trace_event` export holds exactly
//!   one request span per request served, and one route instant per
//!   cluster-routed request.
//! * **Boundedness** — the span ring drops oldest and counts what it
//!   dropped; the span total is not capped.
//! * **Stability** — metrics snapshots serialise to the same bytes for
//!   the same state, whatever order the series were registered in.

use std::time::Duration;

use restream::chip::{ChipApp, ChipConfig, ChipScheduler};
use restream::cluster::{Cluster, ClusterApp, ClusterConfig};
use restream::config::{apps, Network};
use restream::coordinator::{init_conductances, Engine, ExecMode};
use restream::runtime::ArrayF32;
use restream::serve::{ServeConfig, Server, Service};
use restream::telemetry::{json, Json, Registry, Tracer};
use restream::testing::{drive_service, Rng};

const APPS: [&str; 3] = ["iris_ae", "iris_class", "kdd_ae"];
const SAMPLES: usize = 32;

struct Fixture {
    net: Network,
    params: Vec<ArrayF32>,
    xs: Vec<Vec<f32>>,
}

fn fixture(app: &str) -> Fixture {
    let net = apps::network(app).unwrap().clone();
    let params = init_conductances(net.layers, 11);
    let mut rng = Rng::seeded(0x7E1E ^ net.layers[0] as u64);
    let xs: Vec<Vec<f32>> = (0..SAMPLES)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    Fixture { net, params, xs }
}

fn serve_cfg(trace: Option<std::sync::Arc<Tracer>>) -> ServeConfig {
    ServeConfig {
        max_wait: Duration::from_millis(2),
        trace,
        ..ServeConfig::default()
    }
}

/// Serve `xs` through a dedicated server at the given engine shape,
/// optionally traced, and return the outputs in request order.
fn run_server(
    f: &Fixture,
    app: &str,
    workers: usize,
    exec: ExecMode,
    trace: Option<std::sync::Arc<Tracer>>,
    clients: usize,
) -> Vec<Vec<f32>> {
    let engine = Engine::native().with_workers(workers).with_exec(exec);
    let server = Server::start(
        engine,
        f.net.clone(),
        f.params.clone(),
        serve_cfg(trace),
    );
    let outs = drive_service(&server, app, &f.xs, clients);
    server.shutdown();
    outs
}

#[test]
fn tracing_is_bitwise_invisible_in_every_mode() {
    for app in APPS {
        let f = fixture(app);
        for &workers in &[1usize, 4] {
            for &exec in &[ExecMode::DataParallel, ExecMode::Pipelined] {
                let plain =
                    run_server(&f, app, workers, exec, None, 4);
                let reg = Registry::new();
                let tracer = Tracer::new(4096, &reg);
                let traced = run_server(
                    &f,
                    app,
                    workers,
                    exec,
                    Some(tracer.clone()),
                    4,
                );
                assert_eq!(
                    plain, traced,
                    "{app}: tracing changed outputs at workers={workers}, \
                     exec={exec}"
                );
                // one span per request, none lost at this capacity
                assert_eq!(tracer.spans(), SAMPLES as u64);
                assert_eq!(tracer.dropped(), 0);
            }
        }
    }
}

#[test]
fn chip_and_cluster_traces_hold_one_span_per_request() {
    let fixtures: Vec<Fixture> = APPS.iter().map(|a| fixture(a)).collect();
    // Baseline: untraced multi-tenant chip.
    let chip_apps = |fs: &[Fixture]| -> Vec<ChipApp> {
        fs.iter()
            .map(|f| ChipApp { net: f.net.clone(), params: f.params.clone() })
            .collect()
    };
    let cfg = |trace| ChipConfig {
        max_wait: Duration::from_millis(2),
        trace,
        ..ChipConfig::default()
    };
    let chip =
        ChipScheduler::start(Engine::native(), chip_apps(&fixtures), cfg(None))
            .unwrap();
    let expect: Vec<Vec<Vec<f32>>> = fixtures
        .iter()
        .enumerate()
        .map(|(a, f)| drive_service(&chip, APPS[a], &f.xs, 4))
        .collect();
    chip.shutdown();

    // Traced chip: identical outputs, one request span per request.
    let reg = Registry::new();
    let tracer = Tracer::new(4096, &reg);
    let chip = ChipScheduler::start(
        Engine::native(),
        chip_apps(&fixtures),
        cfg(Some(tracer.clone())),
    )
    .unwrap();
    for (a, f) in fixtures.iter().enumerate() {
        let outs = drive_service(&chip, APPS[a], &f.xs, 4);
        assert_eq!(expect[a], outs, "{}: traced chip diverged", APPS[a]);
    }
    let report = chip.shutdown();
    assert_eq!(report.total_requests(), 3 * SAMPLES);
    assert_eq!(tracer.spans(), 3 * SAMPLES as u64);

    let doc_text = tracer.to_chrome_json().to_string();
    let doc = json::parse(&doc_text).expect("chrome export parses");
    let evs = doc.get("traceEvents").expect("traceEvents").items();
    let cat = |c: &str| {
        evs.iter()
            .filter(|e| e.get("cat").and_then(Json::as_str) == Some(c))
            .count()
    };
    assert_eq!(cat("request"), 3 * SAMPLES, "one span per served request");
    assert_eq!(cat("route"), 0, "no cluster, no route instants");
    // every request span names a distinct minted trace id
    let mut ids: Vec<i64> = evs
        .iter()
        .filter(|e| e.get("cat").and_then(Json::as_str) == Some("request"))
        .filter_map(|e| {
            e.get("args")
                .and_then(|a| a.get("trace_id"))
                .and_then(Json::as_i64)
        })
        .collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 3 * SAMPLES, "trace ids must be unique");
    assert!(ids.iter().all(|&id| id > 0), "served spans carry real ids");

    // Traced cluster: identical outputs again, plus one route instant
    // per request.
    let reg = Registry::new();
    let tracer = Tracer::new(4096, &reg);
    let hosted: Vec<ClusterApp> = fixtures
        .iter()
        .map(|f| {
            ClusterApp::new(f.net.clone(), f.params.clone()).replicated(2)
        })
        .collect();
    let cluster = Cluster::start(
        hosted,
        ClusterConfig { chips: 2, chip: cfg(Some(tracer.clone())) },
        |_chip| Ok(Engine::native()),
    )
    .unwrap();
    for (a, f) in fixtures.iter().enumerate() {
        let outs = drive_service(&cluster, APPS[a], &f.xs, 4);
        assert_eq!(expect[a], outs, "{}: traced cluster diverged", APPS[a]);
    }
    let report = cluster.shutdown();
    assert_eq!(report.total_requests(), 3 * SAMPLES);
    assert_eq!(tracer.spans(), 3 * SAMPLES as u64);
    let doc_text = tracer.to_chrome_json().to_string();
    let doc = json::parse(&doc_text).expect("chrome export parses");
    let evs = doc.get("traceEvents").expect("traceEvents").items();
    let cat = |c: &str| {
        evs.iter()
            .filter(|e| e.get("cat").and_then(Json::as_str) == Some(c))
            .count()
    };
    assert_eq!(cat("request"), 3 * SAMPLES);
    assert_eq!(cat("route"), 3 * SAMPLES, "every submit routes once");
}

#[test]
fn trace_ring_overflow_drops_oldest_and_counts() {
    let f = fixture(APPS[0]);
    let reg = Registry::new();
    let tracer = Tracer::new(4, &reg);
    let server = Server::start(
        Engine::native(),
        f.net.clone(),
        f.params.clone(),
        serve_cfg(Some(tracer.clone())),
    );
    drive_service(&server, APPS[0], &f.xs, 4);
    let report = server.shutdown();
    assert_eq!(report.requests, SAMPLES);
    // the span total is not capped by the ring…
    assert_eq!(tracer.spans(), SAMPLES as u64);
    // …the retained window is…
    assert_eq!(tracer.events().len(), 4);
    // …and every evicted event (request + batch spans share the ring)
    // is counted.
    assert_eq!(
        tracer.dropped(),
        (report.requests + report.batches) as u64 - 4
    );
}

#[test]
fn snapshots_are_ordered_and_stable() {
    // Two registries fed the same state in different registration
    // orders must serialise to the same bytes.
    let a = Registry::new();
    a.counter("serve.requests").add(7);
    a.counter("chip.swaps").add(2);
    a.gauge("serve.wall_s").set(1.5);
    a.histogram("serve.total_us").observe(120.0);

    let b = Registry::new();
    b.histogram("serve.total_us").observe(120.0);
    b.gauge("serve.wall_s").set(1.5);
    b.counter("chip.swaps").add(2);
    b.counter("serve.requests").add(7);

    let ja = a.snapshot().to_json().to_string();
    let jb = b.snapshot().to_json().to_string();
    assert_eq!(ja, jb, "registration order leaked into the snapshot");

    // and the document round-trips byte-stably
    let doc = json::parse(&ja).expect("snapshot parses");
    assert_eq!(doc.to_string(), ja);
    assert_eq!(
        doc.get("schema").and_then(Json::as_str),
        Some(restream::telemetry::METRICS_SCHEMA)
    );
}
