//! Cluster determinism: routing must be a pure *where* decision.
//! Every app served from a multi-chip `Cluster` returns
//! **bit-identical** outputs to a dedicated single-app `Server` over
//! the same network and parameters — no matter how many chips the
//! fleet has, how many replicas the app runs, how many clients race
//! the router, or whether placement forced an overflow onto a full
//! chip.
//!
//! Pinned per the acceptance criteria across fleet sizes {1, 2, 4} ×
//! clients {1, 4} on three co-hosted apps (each replicated fleet-wide,
//! so least-loaded routing genuinely picks between chips), plus the
//! unified `serve::Service` surface across all three serving
//! granularities, placement stability across identical clusters, and
//! chip-full spillover.

use std::time::Duration;

use restream::chip::{ChipApp, ChipConfig, ChipScheduler};
use restream::cluster::{
    plan_placement, AppDemand, Cluster, ClusterApp, ClusterConfig,
};
use restream::config::{apps, Network, SystemConfig};
use restream::coordinator::{init_conductances, Engine};
use restream::runtime::ArrayF32;
use restream::serve::{ServeConfig, Server, Service};
use restream::testing::{drive_service, Rng};

const APPS: [&str; 3] = ["iris_ae", "iris_class", "kdd_ae"];
const SAMPLES: usize = 32;

struct Fixture {
    net: Network,
    params: Vec<ArrayF32>,
    xs: Vec<Vec<f32>>,
    /// What a dedicated single-app `Server` answers for each sample.
    expect: Vec<Vec<f32>>,
}

fn fixture(app: &str) -> Fixture {
    let net = apps::network(app).unwrap().clone();
    let params = init_conductances(net.layers, 7);
    let mut rng = Rng::seeded(0xC41F ^ net.layers[0] as u64);
    let xs: Vec<Vec<f32>> = (0..SAMPLES)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    let server = Server::start(
        Engine::native(),
        net.clone(),
        params.clone(),
        ServeConfig::default(),
    );
    let expect = drive_service(&server, app, &xs, 1);
    server.shutdown();
    Fixture { net, params, xs, expect }
}

fn hosted(fixtures: &[Fixture], replicas: usize) -> Vec<ClusterApp> {
    fixtures
        .iter()
        .map(|f| {
            ClusterApp::new(f.net.clone(), f.params.clone())
                .replicated(replicas)
        })
        .collect()
}

fn chip_cfg() -> ChipConfig {
    ChipConfig {
        max_wait: Duration::from_millis(2),
        ..ChipConfig::default()
    }
}

#[test]
fn every_fleet_size_matches_the_dedicated_server() {
    let fixtures: Vec<Fixture> = APPS.iter().map(|a| fixture(a)).collect();
    for &chips in &[1usize, 2, 4] {
        for &clients in &[1usize, 4] {
            // Replicate every app fleet-wide so the least-loaded
            // router genuinely chooses between chips on every submit.
            let cluster = Cluster::start(
                hosted(&fixtures, chips),
                ClusterConfig { chips, chip: chip_cfg() },
                |_chip| Ok(Engine::native()),
            )
            .unwrap();
            for (a, f) in fixtures.iter().enumerate() {
                let outs = drive_service(&cluster, APPS[a], &f.xs, clients);
                for (i, out) in outs.iter().enumerate() {
                    assert_eq!(
                        &f.expect[i], out,
                        "{}: sample {i} diverged at chips={chips}, \
                         clients={clients}",
                        APPS[a]
                    );
                }
            }
            let report = cluster.shutdown();
            assert_eq!(report.n_chips, chips);
            assert_eq!(report.total_requests(), 3 * SAMPLES);
            assert_eq!(report.total_errors(), 0);
            let routed: u64 = report.chips.iter().map(|c| c.routed).sum();
            assert_eq!(routed as usize, 3 * SAMPLES);
            assert!(report.total_energy_j() > 0.0);
            for p in &report.placement {
                assert_eq!(
                    p.chips.len(),
                    chips,
                    "{} must replicate fleet-wide",
                    p.app
                );
                assert!(!p.overflow);
            }
        }
    }
}

#[test]
fn all_three_service_granularities_answer_identically() {
    // One interface, three implementations: a dedicated server, a
    // shared multi-tenant chip, and a two-chip cluster must be
    // indistinguishable through `serve::Service` — bit for bit.
    let fixtures: Vec<Fixture> = APPS.iter().map(|a| fixture(a)).collect();
    let chip_apps: Vec<ChipApp> = fixtures
        .iter()
        .map(|f| ChipApp { net: f.net.clone(), params: f.params.clone() })
        .collect();
    let services: Vec<(&str, Box<dyn Service>)> = vec![
        (
            "chip",
            Box::new(
                ChipScheduler::start(
                    Engine::native(),
                    chip_apps,
                    chip_cfg(),
                )
                .unwrap(),
            ),
        ),
        (
            "cluster",
            Box::new(
                Cluster::start(
                    hosted(&fixtures, 2),
                    ClusterConfig { chips: 2, chip: chip_cfg() },
                    |_chip| Ok(Engine::native()),
                )
                .unwrap(),
            ),
        ),
    ];
    for (kind, svc) in services {
        assert_eq!(svc.apps(), APPS.to_vec(), "{kind}");
        for clients in [1usize, 4] {
            for (a, f) in fixtures.iter().enumerate() {
                let outs =
                    drive_service(svc.as_ref(), APPS[a], &f.xs, clients);
                assert_eq!(
                    f.expect, outs,
                    "{kind}: {} diverged at clients={clients}",
                    APPS[a]
                );
            }
        }
        let stats = svc.shutdown();
        assert_eq!(stats.apps, APPS.len(), "{kind}");
        assert_eq!(stats.requests, 2 * 3 * SAMPLES, "{kind}");
        assert_eq!(stats.errors, 0, "{kind}");
    }
    // The dedicated server is the reference the fixtures were built
    // from; pin that it answers through the trait surface too.
    let f = &fixtures[0];
    let server: Box<dyn Service> = Box::new(Server::start(
        Engine::native(),
        f.net.clone(),
        f.params.clone(),
        ServeConfig::default(),
    ));
    assert_eq!(server.apps(), vec![APPS[0].to_string()]);
    assert_eq!(drive_service(server.as_ref(), APPS[0], &f.xs, 4), f.expect);
    let stats = server.shutdown();
    assert_eq!((stats.apps, stats.requests), (1, SAMPLES));
}

#[test]
fn placement_is_stable_across_identical_clusters() {
    let fixtures: Vec<Fixture> =
        APPS.iter().take(2).map(|a| fixture(a)).collect();
    let start = || {
        Cluster::start(
            hosted(&fixtures, 1),
            ClusterConfig { chips: 4, chip: chip_cfg() },
            |_chip| Ok(Engine::native()),
        )
        .unwrap()
    };
    let first = start();
    let second = start();
    // A restarted router reproduces its placement exactly — the
    // routing-stability half of the determinism contract.
    assert_eq!(first.placement(), second.placement());
    // And the pure planner agrees with what the live clusters ran.
    let demands: Vec<AppDemand> = first
        .placement()
        .apps
        .iter()
        .map(|p| AppDemand {
            app: p.app.clone(),
            cores: p.cores,
            replicas: p.chips.len(),
        })
        .collect();
    let planned = plan_placement(
        &demands,
        4,
        SystemConfig::default().neural_cores,
    )
    .unwrap();
    assert_eq!(&planned, first.placement());
    assert_eq!(first.shutdown().total_requests(), 0);
    assert_eq!(second.shutdown().total_requests(), 0);
}

#[test]
fn full_chips_spill_over_and_still_serve_identically() {
    // Two 2-core chips, three 2-core apps: the third app fits on no
    // chip and is forced (overflow) onto its preferred one, where the
    // chip layer serves it via LRU swapping. Admission spillover must
    // not change a single bit of any answer.
    let fixtures: Vec<Fixture> = APPS.iter().map(|a| fixture(a)).collect();
    let cluster = Cluster::start(
        hosted(&fixtures, 1),
        ClusterConfig {
            chips: 2,
            chip: ChipConfig {
                sys: SystemConfig {
                    neural_cores: 2,
                    ..Default::default()
                },
                max_wait: Duration::ZERO,
                ..ChipConfig::default()
            },
        },
        |_chip| Ok(Engine::native()),
    )
    .unwrap();
    let overflowed: Vec<String> = cluster
        .placement()
        .apps
        .iter()
        .filter(|p| p.overflow)
        .map(|p| p.app.clone())
        .collect();
    assert_eq!(
        overflowed.len(),
        1,
        "exactly one app must spill: {overflowed:?}"
    );
    for (a, f) in fixtures.iter().enumerate() {
        let outs = drive_service(&cluster, APPS[a], &f.xs, 2);
        assert_eq!(f.expect, outs, "{} diverged under spillover", APPS[a]);
    }
    let report = cluster.shutdown();
    assert_eq!(report.total_requests(), 3 * SAMPLES);
    assert_eq!(report.total_errors(), 0);
    // The overcommitted chip really swapped (two apps share 2 cores).
    let swaps: usize = report.chips.iter().map(|c| c.serve.swaps).sum();
    assert!(swaps >= 1, "spillover schedule never swapped");
    assert!(report.summary().contains("overflow"));
}
