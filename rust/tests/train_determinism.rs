//! Training determinism: the data-parallel mini-batch path must be a
//! pure scheduling change, exactly like the sharded inference layer.
//!
//! * `--batch 1` (i.e. [`Engine::train_with`] at batch 1) takes the
//!   untouched sequential stochastic-BP path, so its trained params and
//!   loss curves are **bit-identical** to [`Engine::train`] — the
//!   pre-mini-batch goldens — on every registered application.
//! * `--batch N` results are **bit-identical across worker counts**
//!   {1, 2, 4, 7}: shard boundaries are fixed by the mini-batch size
//!   (never the pool), and gradient partials reduce left-to-right on
//!   one thread (see `coordinator::pool` for the contract).
//!
//! Deliberately exercises the deprecated `train`/`train_with` wrappers:
//! these goldens pin that the thin wrappers still reach the shared
//! internal bodies behind `Engine::fit`.
#![allow(deprecated)]

use restream::config::apps;
use restream::coordinator::Engine;
use restream::runtime::ArrayF32;
use restream::testing::Rng;

/// Worker counts swept below; 7 is deliberately coprime with the
/// 8-sample gradient tile.
const SWEEP: [usize; 3] = [2, 4, 7];

fn rows(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

fn targets_for(rng: &mut Rng, n: usize, t_dim: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(t_dim, -0.4, 0.4)).collect()
}

fn assert_params_eq(a: &[ArrayF32], b: &[ArrayF32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param count");
    for (l, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.data, y.data, "{what}: param {l}");
    }
}

#[test]
fn batch_1_matches_sequential_goldens_on_all_apps() {
    // train_with(batch = 1) must reproduce Engine::train bit for bit —
    // params and loss curve — on every registered network (the big
    // ISOLET stacks run fewer samples to keep debug-mode time sane).
    for net in apps::NETWORKS {
        let n = if net.layers[0] > 500 { 5 } else { 12 };
        let t_dim = net.layers[net.layers.len() - 1];
        let mut rng = Rng::seeded(0xBA7C ^ net.layers[0] as u64);
        let xs = rows(&mut rng, n, net.layers[0]);
        let ts = targets_for(&mut rng, n, t_dim);
        let e = Engine::native();
        let ts_a = ts.clone();
        let (ref_params, ref_rep) = e
            .train(net, &xs, move |i| ts_a[i].clone(), 2, 0.8, 5)
            .unwrap();
        let ts_b = ts.clone();
        let (params, rep) = e
            .train_with(net, &xs, move |i| ts_b[i].clone(), 2, 0.8, 5, 1)
            .unwrap();
        assert_params_eq(&ref_params, &params, net.name);
        assert_eq!(ref_rep.loss_curve, rep.loss_curve, "{}", net.name);
        assert_eq!(rep.batch, 1, "{}", net.name);
        // batch 1 is sequential even on a multi-worker engine
        let e7 = Engine::native().with_workers(7);
        let ts_c = ts.clone();
        let (params7, rep7) = e7
            .train_with(net, &xs, move |i| ts_c[i].clone(), 2, 0.8, 5, 1)
            .unwrap();
        assert_params_eq(&ref_params, &params7, net.name);
        assert_eq!(ref_rep.loss_curve, rep7.loss_curve, "{}", net.name);
    }
}

#[test]
fn batch_n_is_bit_identical_across_worker_counts() {
    // Mini-batch gradients shard over the pool; trained params and
    // loss curves must not depend on how many workers ran the shards.
    // Batch 20 with the 8-sample tile gives 3 shards (last short), and
    // 50 samples leave a 10-sample tail mini-batch each epoch.
    for (name, n, batch) in [
        ("iris_class", 50usize, 20usize),
        ("iris_ae", 50, 20),
        ("kdd_ae", 45, 16),
    ] {
        let net = apps::network(name).unwrap();
        let t_dim = net.layers[net.layers.len() - 1];
        let mut rng = Rng::seeded(0xD00D ^ n as u64);
        let xs = rows(&mut rng, n, net.layers[0]);
        let ts = targets_for(&mut rng, n, t_dim);
        let ts_r = ts.clone();
        let (ref_params, ref_rep) = Engine::native()
            .with_workers(1)
            .train_with(net, &xs, move |i| ts_r[i].clone(), 3, 0.4, 9,
                        batch)
            .unwrap();
        for &w in &SWEEP {
            let ts_w = ts.clone();
            let (params, rep) = Engine::native()
                .with_workers(w)
                .train_with(net, &xs, move |i| ts_w[i].clone(), 3, 0.4,
                            9, batch)
                .unwrap();
            assert_params_eq(
                &ref_params,
                &params,
                &format!("{name} at {w} workers"),
            );
            assert_eq!(
                ref_rep.loss_curve, rep.loss_curve,
                "{name} loss curve at {w} workers"
            );
            assert_eq!(rep.workers, w, "{name}");
            assert_eq!(rep.batch, batch, "{name}");
        }
    }
}

#[test]
fn deep_stack_minibatch_is_worker_invariant() {
    // One multi-layer classifier (4-layer chain rule through the
    // sharded gradient path) at reduced scale.
    let net = apps::network("mnist_class").unwrap();
    let mut rng = Rng::seeded(0xDEE9);
    let n = 18;
    let xs = rows(&mut rng, n, net.layers[0]);
    let ts = targets_for(&mut rng, n, 10);
    let ts_r = ts.clone();
    let (ref_params, _) = Engine::native()
        .with_workers(1)
        .train_with(net, &xs, move |i| ts_r[i].clone(), 1, 0.3, 2, 16)
        .unwrap();
    for &w in &[4usize, 7] {
        let ts_w = ts.clone();
        let (params, _) = Engine::native()
            .with_workers(w)
            .train_with(net, &xs, move |i| ts_w[i].clone(), 1, 0.3, 2, 16)
            .unwrap();
        assert_params_eq(
            &ref_params,
            &params,
            &format!("mnist_class at {w} workers"),
        );
    }
}

#[test]
fn dr_pipeline_minibatch_is_worker_invariant() {
    // The layerwise DR pipeline threads the same mini-batch machinery
    // through every stage; encoder params must be worker-invariant too.
    let net = apps::network("mnist_dr").unwrap();
    let mut rng = Rng::seeded(0xD12);
    let xs = rows(&mut rng, 10, net.layers[0]);
    let (ref_enc, ref_reports) = Engine::native()
        .with_workers(1)
        .train_dr(net, &xs, 1, 0.3, 4, 8)
        .unwrap();
    let (enc, reports) = Engine::native()
        .with_workers(4)
        .train_dr(net, &xs, 1, 0.3, 4, 8)
        .unwrap();
    assert_params_eq(&ref_enc, &enc, "mnist_dr encoder");
    assert_eq!(ref_reports.len(), reports.len());
    for (s, (a, b)) in ref_reports.iter().zip(&reports).enumerate() {
        assert_eq!(a.loss_curve, b.loss_curve, "stage {s}");
    }
}

#[test]
fn minibatch_losses_use_start_of_batch_params() {
    // One mini-batch spanning the whole epoch (batch = n = 10, so two
    // 8/2 gradient shards): every reported per-sample loss must be
    // computed under the start-of-batch parameter snapshot, so the
    // epoch-mean loss equals the mean of single-sample grad_batch
    // losses under the *initial* conductances. A regression that
    // applies updates between shards, or scores losses after the
    // update, shifts the second shard's losses by ~the first update's
    // step — orders of magnitude above the summation-order tolerance.
    use restream::coordinator::init_conductances;
    use restream::runtime::{ArrayF32 as Arr, Backend, NativeBackend};
    let net = apps::network("iris_class").unwrap();
    let mut rng = Rng::seeded(77);
    let n = 10;
    let xs = rows(&mut rng, n, 4);
    let ts = targets_for(&mut rng, n, 1);
    let seed = 3u64;
    let ts_c = ts.clone();
    let (_, rep) = Engine::native()
        .with_workers(2)
        .train_with(net, &xs, move |i| ts_c[i].clone(), 1, 0.5, seed, n)
        .unwrap();
    assert_eq!(rep.loss_curve.len(), 1);
    let params = init_conductances(net.layers, seed);
    let backend = NativeBackend;
    let mut sum = 0.0f32;
    for i in 0..n {
        let gb = backend
            .grad_batch(
                "g",
                &params,
                &Arr::row(xs[i].clone()),
                &Arr::row(ts[i].clone()),
            )
            .unwrap();
        sum += gb.losses[0];
    }
    let expect = sum / n as f32;
    let got = rep.loss_curve[0];
    assert!(
        (got - expect).abs() < 1e-5,
        "epoch loss {got} != frozen-params mean {expect}"
    );
}
