//! Worker-failure recovery: a worker dying mid-epoch is a pure
//! scheduling event. The pool reassigns the dead worker's shard to a
//! survivor, the left-to-right gradient reduction is unchanged, and the
//! trained conductances are **bit-identical** to the healthy run —
//! while `TrainReport::recovered_shards` records that the recovery
//! actually happened.
//!
//! The failure is injected deterministically through
//! `Engine::inject_worker_failure` (the `faultinject` feature, enabled
//! for tests by the crate's self dev-dependency): the next sharded
//! operation kills the worker that picks up the given shard index
//! mid-computation.
//!
//! Deliberately exercises the deprecated `train_*` wrappers: these
//! tests pin that the thin wrappers still reach the shared internal
//! bodies behind `Engine::fit`.
#![allow(deprecated)]

use restream::config::apps;
use restream::coordinator::Engine;
use restream::runtime::ArrayF32;
use restream::testing::Rng;

fn rows(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

fn targets_for(rng: &mut Rng, n: usize, t_dim: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(t_dim, -0.4, 0.4)).collect()
}

fn assert_params_eq(a: &[ArrayF32], b: &[ArrayF32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param count");
    for (l, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.data, y.data, "{what}: param {l}");
    }
}

#[test]
fn worker_death_mid_epoch_is_bit_invisible_at_2_and_4_workers() {
    // 40 samples at batch 16 → mini-batches of 16/16/8, each gradient
    // pass sharded into 8-sample tiles → shard 1 exists in every
    // mini-batch. Killing its worker during the first mini-batch of
    // epoch 1 (mid-epoch by construction) must not change a single bit
    // of the trained conductances or the loss curve.
    let net = apps::network("iris_class").unwrap();
    let mut rng = Rng::seeded(0xFA11);
    let n = 40;
    let xs = rows(&mut rng, n, net.layers[0]);
    let ts = targets_for(&mut rng, n, 1);
    for &w in &[2usize, 4] {
        let what = format!("iris_class at {w} workers");
        let ts_h = ts.clone();
        let (ref_params, ref_rep) = Engine::native()
            .with_workers(w)
            .train_with(net, &xs, move |i| ts_h[i].clone(), 2, 0.4, 9, 16)
            .unwrap();
        assert_eq!(
            ref_rep.recovered_shards, 0,
            "{what}: healthy run must report no recoveries"
        );

        let engine = Engine::native().with_workers(w);
        engine.inject_worker_failure(1);
        let ts_f = ts.clone();
        let (params, rep) = engine
            .train_with(net, &xs, move |i| ts_f[i].clone(), 2, 0.4, 9, 16)
            .unwrap();
        assert_params_eq(&ref_params, &params, &what);
        assert_eq!(rep.loss_curve, ref_rep.loss_curve, "{what}");
        assert_eq!(
            rep.recovered_shards, 1,
            "{what}: the one-shot failure must surface as exactly one \
             recovered shard"
        );
        assert_eq!(rep.samples_seen, ref_rep.samples_seen, "{what}");
    }
}

#[test]
fn recovery_on_the_last_short_shard_is_bit_invisible() {
    // kdd_ae at batch 20 shards into 8/8/4 tiles; kill the short tail
    // shard (index 2) — reassignment of a partial tile must fold back
    // into the identical position.
    let net = apps::network("kdd_ae").unwrap();
    let mut rng = Rng::seeded(0xFA12);
    let n = 40;
    let xs = rows(&mut rng, n, net.layers[0]);
    let xs_h = xs.clone();
    let (ref_params, ref_rep) = Engine::native()
        .with_workers(4)
        .train_with(net, &xs, move |i| xs_h[i].clone(), 2, 0.4, 3, 20)
        .unwrap();

    let engine = Engine::native().with_workers(4);
    engine.inject_worker_failure(2);
    let xs_f = xs.clone();
    let (params, rep) = engine
        .train_with(net, &xs, move |i| xs_f[i].clone(), 2, 0.4, 3, 20)
        .unwrap();
    assert_params_eq(&ref_params, &params, "kdd_ae tail shard");
    assert_eq!(rep.loss_curve, ref_rep.loss_curve);
    assert_eq!(rep.recovered_shards, 1);
}

#[test]
fn worker_death_then_checkpoint_resume_still_bit_identical() {
    // The two recovery mechanisms compose: a worker dies mid-epoch in
    // the interrupted half of a checkpointed run, the run halts at the
    // epoch boundary, and the resumed half finishes — all bit-identical
    // to the uninterrupted healthy run.
    use restream::coordinator::CheckpointOpts;
    let net = apps::network("iris_ae").unwrap();
    let mut rng = Rng::seeded(0xFA13);
    let n = 24;
    let xs = rows(&mut rng, n, net.layers[0]);
    let xs_h = xs.clone();
    let (ref_params, ref_rep) = Engine::native()
        .with_workers(2)
        .train_with(net, &xs, move |i| xs_h[i].clone(), 4, 0.5, 7, 8)
        .unwrap();

    let dir = std::env::temp_dir().join(format!(
        "restream-fault-ckpt-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let engine = Engine::native().with_workers(2);
    engine.inject_worker_failure(0);
    let mut opts = CheckpointOpts::new(&dir);
    opts.stop_after = Some(2);
    let xs_a = xs.clone();
    let (_, cut_rep) = engine
        .train_checkpointed(net, &xs, move |i| xs_a[i].clone(), 4, 0.5,
                            7, 8, &opts)
        .unwrap();
    assert_eq!(cut_rep.recovered_shards, 1);

    let mut opts = CheckpointOpts::new(&dir);
    opts.resume = true;
    let xs_b = xs.clone();
    let (params, rep) = engine
        .train_checkpointed(net, &xs, move |i| xs_b[i].clone(), 4, 0.5,
                            7, 8, &opts)
        .unwrap();
    assert_params_eq(&ref_params, &params, "fault + checkpoint resume");
    assert_eq!(rep.loss_curve, ref_rep.loss_curve);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_inference_also_recovers_bit_identically() {
    // The recovery protocol lives in the pool, not the training loop —
    // a batched inference run over the same pool recovers the same way.
    let net = apps::network("iris_class").unwrap();
    let mut rng = Rng::seeded(0xFA14);
    let xs = rows(&mut rng, 96, net.layers[0]);
    let params = restream::coordinator::init_conductances(net.layers, 11);
    let ref_out = Engine::native()
        .with_workers(3)
        .infer(net, &params, &xs)
        .unwrap();

    let engine = Engine::native().with_workers(3);
    engine.inject_worker_failure(0);
    let out = engine.infer(net, &params, &xs).unwrap();
    assert_eq!(ref_out, out, "recovered inference outputs");
    let rep = engine.last_parallel_report().unwrap();
    assert_eq!(rep.recovered_shards, vec![0]);
}
