//! Serving determinism: micro-batching must be a pure scheduling
//! change. A request's result is **bit-identical** to single-sample
//! sequential evaluation no matter how many concurrent clients raced
//! it into the queue or which micro-batch it landed in — batching only
//! moves a sample's row inside the input matrix, the forward math is
//! row-independent, and the sharded execution underneath is already
//! bit-identical at any worker count (`coordinator::pool`).
//!
//! Pinned across clients ∈ {1, 4, 16} × max-batch ∈ {1, 64} per the
//! acceptance criteria, on a cheap app and a mid-sized one. The
//! client fan-out runs through `testing::drive_service`, the shared
//! harness every `serve::Service` implementation (dedicated server,
//! multi-tenant chip, multi-chip cluster) is pinned with.

use std::time::Duration;

use restream::config::{apps, Network};
use restream::coordinator::{init_conductances, Engine};
use restream::runtime::ArrayF32;
use restream::serve::{ServeConfig, Server};
use restream::testing::{drive_service, Rng};

/// The reference: each sample evaluated alone (batch of one) on the
/// sequential 1-worker engine.
fn single_sample_reference(
    net: &Network,
    params: &[ArrayF32],
    xs: &[Vec<f32>],
) -> Vec<Vec<f32>> {
    let engine = Engine::native().with_workers(1);
    xs.iter()
        .map(|x| {
            engine
                .infer(net, params, std::slice::from_ref(x))
                .unwrap()
                .pop()
                .unwrap()
        })
        .collect()
}

#[test]
fn concurrent_requests_match_single_sample_sequential() {
    for app in ["iris_class", "kdd_ae"] {
        let net = apps::network(app).unwrap();
        let params = init_conductances(net.layers, 9);
        let mut rng = Rng::seeded(0x5E12 ^ net.layers[0] as u64);
        let xs: Vec<Vec<f32>> = (0..96)
            .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
            .collect();
        let expect = single_sample_reference(net, &params, &xs);
        for &clients in &[1usize, 4, 16] {
            for &max_batch in &[1usize, 64] {
                // A wide-open wait forces real coalescing when
                // max_batch allows it; max_batch = 1 pins the
                // sequential-dispatch edge of the same path.
                let cfg = ServeConfig {
                    max_batch,
                    max_wait: Duration::from_millis(2),
                    ..ServeConfig::default()
                };
                let server = Server::start(
                    Engine::native().with_workers(2),
                    net.clone(),
                    params.clone(),
                    cfg,
                );
                let outs = drive_service(&server, app, &xs, clients);
                for (i, out) in outs.iter().enumerate() {
                    assert_eq!(
                        &expect[i], out,
                        "{app}: sample {i} diverged at clients={clients}, \
                         max_batch={max_batch}"
                    );
                }
                let report = server.shutdown();
                assert_eq!(report.requests, xs.len(), "{app}");
                assert_eq!(report.errors, 0, "{app}");
                if max_batch == 1 {
                    // sequential dispatch: one batch per request
                    assert_eq!(report.batches, xs.len(), "{app}");
                }
            }
        }
    }
}

#[test]
fn results_are_independent_of_the_batching_window() {
    // Same request stream through aggressively different windows (never
    // wait vs. always fill) — identical outputs, only timing may move.
    let net = apps::network("iris_ae").unwrap();
    let params = init_conductances(net.layers, 21);
    let mut rng = Rng::seeded(0xBA7C);
    let xs: Vec<Vec<f32>> = (0..50)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    let mut outputs: Vec<Vec<Vec<f32>>> = Vec::new();
    for max_wait in [Duration::ZERO, Duration::from_millis(5)] {
        let cfg = ServeConfig {
            max_wait,
            ..ServeConfig::default()
        };
        let server = Server::start(
            Engine::native(),
            net.clone(),
            params.clone(),
            cfg,
        );
        let client = server.client();
        let outs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| client.call(x.clone()).unwrap().out)
            .collect();
        drop(client);
        server.shutdown();
        outputs.push(outs);
    }
    assert_eq!(outputs[0], outputs[1]);
    assert_eq!(outputs[0], single_sample_reference(net, &params, &xs));
}
