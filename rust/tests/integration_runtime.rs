//! Integration: the [`Backend`] contract.
//!
//! The native backend (the default compute path — no artifacts, Python
//! or XLA anywhere) must execute every Table I application's training
//! and recognition graphs out of the box, agree bitwise with the
//! pure-Rust reference network, and honour the clustering-core
//! register semantics. The artifact-executing PJRT path keeps its
//! original contract tests behind the `pjrt` cargo feature (ignored by
//! default: they need a real XLA install plus `make artifacts`).

use restream::config::{apps, AppKind};
use restream::coordinator::init_conductances;
use restream::nn::Mlp;
use restream::runtime::{ArrayF32, Backend, FwdMode, NativeBackend};
use restream::testing::Rng;

#[test]
fn every_registered_network_trains_and_infers_out_of_the_box() {
    // The backend twin of "every artifact loads and validates": for
    // every Table I app, one training step runs, preserves parameter
    // shapes, and returns a finite loss; the forward graph produces the
    // output rows the app expects.
    let b = NativeBackend;
    let mut rng = Rng::seeded(0);
    for net in apps::NETWORKS {
        let params = init_conductances(net.layers, 7);
        let dims = net.layers[0];
        let outs = net.layers[net.layers.len() - 1];
        if net.kind == AppKind::DimReduction {
            // stage-0 pretraining graph (deeper stages differ only in
            // dims; keeping one stage bounds debug-build test time)
            let (n_in, n_hid) = net.dr_stages()[0];
            let sp = init_conductances(&[n_in, n_hid, n_in], 7);
            let shapes: Vec<Vec<usize>> =
                sp.iter().map(|p| p.shape.clone()).collect();
            let x = ArrayF32::row(rng.vec_uniform(n_in, -0.5, 0.5));
            let (next, loss) = b
                .train_step(&net.stage_artifact(0), sp, &x, &x, 0.5)
                .unwrap_or_else(|e| panic!("{} stage0: {e:#}", net.name));
            assert!(loss.is_finite(), "{} stage0 loss", net.name);
            for (p, want) in next.iter().zip(&shapes) {
                assert_eq!(&p.shape, want, "{} stage0 shapes", net.name);
            }
        } else {
            let shapes: Vec<Vec<usize>> =
                params.iter().map(|p| p.shape.clone()).collect();
            let x = ArrayF32::row(rng.vec_uniform(dims, -0.5, 0.5));
            let t = ArrayF32::row(rng.vec_uniform(outs, -0.4, 0.4));
            let (next, loss) = b
                .train_step(&net.train_artifact(), params.clone(), &x, &t, 0.5)
                .unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
            assert!(loss.is_finite(), "{} loss", net.name);
            for (p, want) in next.iter().zip(&shapes) {
                assert_eq!(&p.shape, want, "{} shapes", net.name);
            }
        }
        // forward graph (for DR apps the full parameter chain *is* the
        // encoder stack); small batch keeps the isolet nets cheap
        let batch = 4;
        let xs = ArrayF32::matrix(
            batch,
            dims,
            rng.vec_uniform(batch * dims, -0.5, 0.5),
        )
        .unwrap();
        let fwd = b
            .forward_batch(&net.fwd_artifact(), FwdMode::for_kind(net.kind),
                           &params, &xs)
            .unwrap_or_else(|e| panic!("{} fwd: {e:#}", net.name));
        assert_eq!(fwd[0].shape, vec![batch, outs], "{} fwd", net.name);
        if net.kind == AppKind::Autoencoder {
            assert_eq!(fwd.len(), 2, "{}: AE returns (recon, code)",
                       net.name);
            assert_eq!(fwd[1].shape, vec![batch, net.layers[1]],
                       "{} code", net.name);
        } else {
            assert_eq!(fwd.len(), 1, "{} output count", net.name);
        }
    }
}

#[test]
fn forward_batch_matches_reference_network_bitwise() {
    // The batched backend path and the per-sample pure-Rust reference
    // (`nn::Mlp`, chip constraint) implement the same math with the
    // same quantisers — outputs must agree exactly.
    let b = NativeBackend;
    let net = apps::network("kdd_ae").unwrap();
    let params = init_conductances(net.layers, 42);
    let mlp = Mlp::from_params(net.layers, &params);

    let mut rng = Rng::seeded(7);
    let batch = apps::FWD_BATCH;
    let dims = net.layers[0];
    let data = rng.vec_uniform(batch * dims, -0.5, 0.5);
    let xs = ArrayF32::matrix(batch, dims, data.clone()).unwrap();
    let outs = b
        .forward_batch(&net.fwd_artifact(), FwdMode::ReconAndCode,
                       &params, &xs)
        .unwrap();
    let recon = &outs[0];
    for i in 0..batch {
        let want = mlp.forward(&data[i * dims..(i + 1) * dims]);
        assert_eq!(recon.row_slice(i), &want[..], "sample {i}");
    }
}

#[test]
fn train_step_is_deterministic() {
    let b = NativeBackend;
    let net = apps::network("iris_class").unwrap();
    let mut rng = Rng::seeded(3);
    let x = ArrayF32::row(rng.vec_uniform(4, -0.5, 0.5));
    let t = ArrayF32::row(vec![0.4]);
    let run = || {
        b.train_step(
            &net.train_artifact(),
            init_conductances(net.layers, 5),
            &x,
            &t,
            1.0,
        )
        .unwrap()
    };
    let (p1, l1) = run();
    let (p2, l2) = run();
    assert_eq!(l1, l2);
    for (a, c) in p1.iter().zip(&p2) {
        assert_eq!(a.data, c.data);
    }
}

#[test]
fn kmeans_batch_honours_core_register_semantics() {
    let b = NativeBackend;
    let app = apps::kmeans_app("mnist_kmeans").unwrap();
    let (d, k) = (app.dims, app.clusters);
    let mut rng = Rng::seeded(3);
    let batch = apps::FWD_BATCH;
    let x = rng.vec_uniform(batch * d, -0.5, 0.5);
    let centres = rng.vec_uniform(k * d, -0.5, 0.5);
    let step = b
        .kmeans_batch(
            &app.step_artifact(),
            &ArrayF32::matrix(batch, d, x.clone()).unwrap(),
            &ArrayF32::matrix(k, d, centres.clone()).unwrap(),
        )
        .unwrap();
    // assignment is exactly the reference argmin
    let km = restream::kmeans::KMeans { k, dims: d, centres };
    for i in 0..batch {
        assert_eq!(step.assign[i], km.assign_one(&x[i * d..(i + 1) * d]),
                   "sample {i}");
    }
    // counts sum to the batch; accumulators sum to the batch's samples
    assert_eq!(step.counts.iter().sum::<f32>() as usize, batch);
    for dd in 0..d {
        let total: f32 =
            (0..k).map(|c| step.acc[c * d + dd]).sum();
        let want: f32 = (0..batch).map(|i| x[i * d + dd]).sum();
        assert!((total - want).abs() < 1e-4, "dim {dd}: {total} vs {want}");
    }
}

#[test]
fn oversized_input_is_rejected_with_shape_error() {
    let b = NativeBackend;
    let net = apps::network("kdd_ae").unwrap();
    let params = init_conductances(net.layers, 0);
    let xs = ArrayF32::matrix(1, 7, vec![0.0; 7]).unwrap();
    let err = b
        .forward_batch(&net.fwd_artifact(), FwdMode::ReconAndCode,
                       &params, &xs)
        .unwrap_err();
    assert!(err.to_string().contains("crossbar"), "{err}");
}

/// Artifact-path contract (PJRT backend). These need a real `xla`
/// crate (not the vendored stub), an XLA extension install and `make
/// artifacts`, so they are ignored by default even under the feature.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use restream::runtime::Runtime;

    fn rt() -> Runtime {
        Runtime::open_default().expect("run `make artifacts` first")
    }

    #[test]
    #[ignore = "needs a real XLA install plus `make artifacts`"]
    fn every_registered_artifact_loads_and_validates() {
        let rt = rt();
        for net in apps::NETWORKS {
            let mut names = vec![net.fwd_artifact()];
            if net.kind != AppKind::DimReduction {
                names.push(net.train_artifact());
            } else {
                for s in 0..net.dr_stages().len() {
                    names.push(net.stage_artifact(s));
                }
            }
            for name in names {
                let exe = rt.load(&name).unwrap_or_else(|e| {
                    panic!("loading {name}: {e:#}");
                });
                assert!(!exe.meta.inputs.is_empty(), "{name} has no inputs");
                assert!(!exe.meta.outputs.is_empty(),
                        "{name} has no outputs");
            }
        }
        for a in apps::KMEANS_APPS {
            rt.load(&a.step_artifact()).expect("kmeans artifact");
        }
    }

    #[test]
    #[ignore = "needs a real XLA install plus `make artifacts`"]
    fn executable_cache_reuses_compilations() {
        let rt = rt();
        let a = rt.load("kdd_ae_fwd_b64").unwrap();
        let b = rt.load("kdd_ae_fwd_b64").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    #[ignore = "needs a real XLA install plus `make artifacts`"]
    fn fwd_artifact_matches_rust_reference() {
        // The PJRT-executed kernel chain and the Rust ideal-crossbar
        // path implement the same math with the same quantisers; after
        // the 3-bit output ADC they must agree exactly on almost every
        // code, and within one ADC step everywhere (float association
        // differences can flip a borderline rounding).
        let rt = rt();
        let net = apps::network("kdd_ae").unwrap();
        let exe = rt.load(&net.fwd_artifact()).unwrap();
        let params = init_conductances(net.layers, 42);
        let mlp = Mlp::from_params(net.layers, &params);

        let mut rng = Rng::seeded(7);
        let batch = apps::FWD_BATCH;
        let dims = net.layers[0];
        let data = rng.vec_uniform(batch * dims, -0.5, 0.5);
        let mut inputs = params.clone();
        inputs.push(ArrayF32::matrix(batch, dims, data.clone()).unwrap());
        let outs = exe.run(&inputs).unwrap();
        let recon = &outs[0];

        let lsb =
            1.0 / ((1 << restream::config::hwspec::OUT_BITS) - 1) as f32;
        let mut exact = 0usize;
        let mut total = 0usize;
        for bi in 0..batch {
            let x = &data[bi * dims..(bi + 1) * dims];
            let want = mlp.forward(x);
            let got = recon.row_slice(bi);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                total += 1;
                if (g - w).abs() < 1e-6 {
                    exact += 1;
                } else {
                    assert!(
                        (g - w).abs() <= lsb + 1e-6,
                        "divergence beyond one ADC step: {g} vs {w}"
                    );
                }
            }
        }
        assert!(
            exact as f64 / total as f64 > 0.99,
            "only {exact}/{total} codes identical"
        );
    }

    #[test]
    #[ignore = "needs a real XLA install plus `make artifacts`"]
    fn meta_validation_rejects_wrong_shapes() {
        let rt = rt();
        let exe = rt.load("kdd_ae_fwd_b64").unwrap();
        // right count, wrong batch
        let net = apps::network("kdd_ae").unwrap();
        let mut inputs = init_conductances(net.layers, 0);
        inputs.push(ArrayF32::matrix(1, 41, vec![0.0; 41]).unwrap());
        let err = exe.run(&inputs).unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }
}
