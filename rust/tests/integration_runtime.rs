//! Integration: artifacts → PJRT runtime → numerics.
//!
//! Requires `make artifacts` (the Makefile's `cargotest` target orders
//! this). These tests prove the cross-language contract: the HLO the
//! python side lowered computes exactly what the Rust reference
//! (`crossbar::ideal` / `nn::Mlp`) computes.

use restream::config::{apps, hwspec as hw};
use restream::coordinator::init_conductances;
use restream::nn::Mlp;
use restream::runtime::{ArrayF32, Runtime};

fn rt() -> Runtime {
    Runtime::open_default().expect("run `make artifacts` first")
}

#[test]
fn every_registered_artifact_loads_and_validates() {
    let rt = rt();
    for net in apps::NETWORKS {
        let mut names = vec![net.fwd_artifact()];
        if net.kind != restream::config::AppKind::DimReduction {
            names.push(net.train_artifact());
        } else {
            for s in 0..net.dr_stages().len() {
                names.push(net.stage_artifact(s));
            }
        }
        for name in names {
            let exe = rt.load(&name).unwrap_or_else(|e| {
                panic!("loading {name}: {e:#}");
            });
            assert!(!exe.meta.inputs.is_empty(), "{name} has no inputs");
            assert!(!exe.meta.outputs.is_empty(), "{name} has no outputs");
        }
    }
    for a in apps::KMEANS_APPS {
        rt.load(&a.step_artifact()).expect("kmeans artifact");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let rt = rt();
    let a = rt.load("kdd_ae_fwd_b64").unwrap();
    let b = rt.load("kdd_ae_fwd_b64").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert_eq!(rt.cached(), 1);
}

#[test]
fn fwd_artifact_matches_rust_reference_bitwise() {
    // The PJRT-executed kernel chain and the Rust ideal-crossbar path
    // implement the same math with the same quantisers; after the 3-bit
    // output ADC they must agree exactly on almost every code, and
    // within one ADC step everywhere (float association differences can
    // flip a borderline rounding).
    let rt = rt();
    let net = apps::network("kdd_ae").unwrap();
    let exe = rt.load(&net.fwd_artifact()).unwrap();
    let params = init_conductances(net.layers, 42);
    let mlp = Mlp::from_params(net.layers, &params);

    let mut rng = restream::testing::Rng::seeded(7);
    let batch = apps::FWD_BATCH;
    let dims = net.layers[0];
    let data = rng.vec_uniform(batch * dims, -0.5, 0.5);
    let mut inputs = params.clone();
    inputs.push(ArrayF32::matrix(batch, dims, data.clone()).unwrap());
    let outs = exe.run(&inputs).unwrap();
    let recon = &outs[0];

    let lsb = 1.0 / ((1 << hw::OUT_BITS) - 1) as f32;
    let mut exact = 0usize;
    let mut total = 0usize;
    for b in 0..batch {
        let x = &data[b * dims..(b + 1) * dims];
        let want = mlp.forward(x);
        let got = recon.row_slice(b);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            total += 1;
            if (g - w).abs() < 1e-6 {
                exact += 1;
            } else {
                assert!(
                    (g - w).abs() <= lsb + 1e-6,
                    "divergence beyond one ADC step: {g} vs {w}"
                );
            }
        }
    }
    assert!(
        exact as f64 / total as f64 > 0.99,
        "only {exact}/{total} codes identical"
    );
}

#[test]
fn meta_validation_rejects_wrong_shapes() {
    let rt = rt();
    let exe = rt.load("kdd_ae_fwd_b64").unwrap();
    // right count, wrong batch
    let net = apps::network("kdd_ae").unwrap();
    let mut inputs = init_conductances(net.layers, 0);
    inputs.push(ArrayF32::matrix(1, 41, vec![0.0; 41]).unwrap());
    let err = exe.run(&inputs).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}

#[test]
fn kmeans_step_artifact_matches_rust_reference() {
    let rt = rt();
    let app = apps::kmeans_app("mnist_kmeans").unwrap();
    let exe = rt.load(&app.step_artifact()).unwrap();
    let (d, k) = (app.dims, app.clusters);
    let mut rng = restream::testing::Rng::seeded(3);
    let x = rng.vec_uniform(apps::FWD_BATCH * d, -0.5, 0.5);
    let centres = rng.vec_uniform(k * d, -0.5, 0.5);
    let outs = exe
        .run(&[
            ArrayF32::matrix(apps::FWD_BATCH, d, x.clone()).unwrap(),
            ArrayF32::matrix(k, d, centres.clone()).unwrap(),
        ])
        .unwrap();
    let assign = &outs[0];
    let km = restream::kmeans::KMeans { k, dims: d, centres };
    for i in 0..apps::FWD_BATCH {
        let want = km.assign_one(&x[i * d..(i + 1) * d]);
        assert_eq!(assign.data[i] as usize, want, "sample {i}");
    }
    // counts sum to the batch
    let count_sum: f32 = outs[2].data.iter().sum();
    assert_eq!(count_sum as usize, apps::FWD_BATCH);
}
