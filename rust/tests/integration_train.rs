//! Integration: full training pipelines through the coordinator —
//! the paper's section VI experiments at test scale, runnable out of
//! the box on the default (native) backend. Setting
//! `RESTREAM_BACKEND=pjrt` re-runs the same pipelines through the
//! artifact path (requires `--features pjrt` + `make artifacts`).
//!
//! Deliberately keeps exercising the deprecated `train`/`train_with`
//! wrappers next to `Engine::fit`: these tests pin that the thin
//! wrappers still reach the shared internal bodies (see
//! `fit_is_bit_identical_to_the_deprecated_wrappers`).
#![allow(deprecated)]

use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions};
use restream::{datasets, metrics};

fn engine() -> Engine {
    Engine::open_default().expect("backend construction failed")
}

#[test]
fn iris_supervised_training_converges_and_classifies() {
    // Paper Fig 16: the network learns the Iris classifier on chip.
    let e = engine();
    let net = apps::network("iris_class").unwrap();
    let ds = datasets::iris(0);
    let (train, test) = ds.split(0.8, 0);
    let xs = train.rows();
    let (params, rep) = e
        .train(net, &xs, |i| train.target(i, 1), 15, 1.0, 0)
        .unwrap();
    assert_eq!(rep.epochs, 15);
    assert_eq!(rep.samples_seen, 15 * xs.len());
    let first = rep.loss_curve[0];
    let last = *rep.loss_curve.last().unwrap();
    assert!(last < first * 0.5, "loss {first} -> {last}");
    let preds = e.classify(net, &params, &test.rows()).unwrap();
    let truth: Vec<usize> = test.y.iter().map(|&y| y.min(1)).collect();
    assert!(metrics::accuracy(&preds, &truth) > 0.9);
}

#[test]
fn iris_autoencoder_separates_classes_in_code_space() {
    // Paper Fig 17: 4->2->4 AE codes cluster by class.
    let e = engine();
    let net = apps::network("iris_ae").unwrap();
    let ds = datasets::iris(0);
    let xs = ds.rows();
    let xs_t = xs.clone();
    let (params, rep) = e
        .train(net, &xs, move |i| xs_t[i].clone(), 30, 0.8, 1)
        .unwrap();
    assert!(rep.loss_curve.last().unwrap() < &rep.loss_curve[0]);
    let codes = e.encode(net, &params, &xs).unwrap();
    assert_eq!(codes[0].len(), 2);
    // class centroids in code space must be separated vs within-class
    // spread (the "potentially linearly separated" claim, weak form)
    let centroid = |c: usize| -> [f64; 2] {
        let mut m = [0.0; 2];
        let mut n = 0;
        for i in 0..xs.len() {
            if ds.y[i] == c {
                m[0] += codes[i][0] as f64;
                m[1] += codes[i][1] as f64;
                n += 1;
            }
        }
        [m[0] / n as f64, m[1] / n as f64]
    };
    let c0 = centroid(0);
    let c1 = centroid(1);
    let c2 = centroid(2);
    let d01 = ((c0[0] - c1[0]).powi(2) + (c0[1] - c1[1]).powi(2)).sqrt();
    let d02 = ((c0[0] - c2[0]).powi(2) + (c0[1] - c2[1]).powi(2)).sqrt();
    assert!(d01 > 0.05, "setosa/versicolor centroids collapsed: {d01}");
    assert!(d02 > 0.05, "setosa/virginica centroids collapsed: {d02}");
}

#[test]
fn kdd_anomaly_detection_has_paper_shape() {
    // Paper Figs 18-20: attacks reconstruct worse than normals.
    let e = engine();
    let net = apps::network("kdd_ae").unwrap();
    let k = datasets::kdd(1200, 250, 250, 0);
    let xs = k.train.rows();
    let xs_t = xs.clone();
    let (params, _) = e
        .train(net, &xs, move |i| xs_t[i].clone(), 2, 0.8, 0)
        .unwrap();
    let scores = e.anomaly_scores(net, &params, &k.test.rows()).unwrap();
    let pts = metrics::roc_sweep(&scores, &k.test_attack, 100);
    let auc = metrics::auc(&pts);
    assert!(auc > 0.9, "auc {auc}");
    assert!(metrics::tpr_at_fpr(&pts, 0.04) > 0.8);
}

#[test]
fn kmeans_through_clustering_core_artifact() {
    let e = engine();
    let app = apps::kmeans_app("mnist_kmeans").unwrap();
    let ds = datasets::class_blobs("t", app.dims, app.clusters, 400, 0.15, 3);
    // plain k-means with sampled-centre init (what the core does) lands
    // in local optima; take the best of a few seeds like any practitioner
    let best = (0..3)
        .map(|seed| {
            let (_, assign) = e.kmeans(app, &ds.rows(), 10, seed).unwrap();
            metrics::purity(&assign, &ds.y, app.clusters, ds.classes)
        })
        .fold(0.0f64, f64::max);
    assert!(best > 0.7, "best purity {best}");
}

#[test]
fn kmeans_handles_non_multiple_batch_sizes() {
    // padding path: 70 samples with batch 64
    let e = engine();
    let app = apps::kmeans_app("mnist_kmeans").unwrap();
    let ds = datasets::class_blobs("t", app.dims, app.clusters, 70, 0.2, 5);
    let (_, assign) = e.kmeans(app, &ds.rows(), 5, 0).unwrap();
    assert_eq!(assign.len(), 70);
}

#[test]
fn iris_minibatch_training_converges_and_classifies() {
    // The data-parallel path must not just be deterministic — it must
    // still learn. Mini-batch 8 accumulates summed gradients (one
    // pulse per batch), so a lower lr than the per-sample run.
    let e = engine().with_workers(4);
    let net = apps::network("iris_class").unwrap();
    let ds = datasets::iris(0);
    let (train, test) = ds.split(0.8, 0);
    let xs = train.rows();
    let (params, rep) = e
        .train_with(net, &xs, |i| train.target(i, 1), 15, 0.5, 0, 8)
        .unwrap();
    assert_eq!(rep.epochs, 15);
    assert_eq!(rep.batch, 8);
    let first = rep.loss_curve[0];
    let last = *rep.loss_curve.last().unwrap();
    assert!(last < first * 0.5, "loss {first} -> {last}");
    let preds = e.classify(net, &params, &test.rows()).unwrap();
    let truth: Vec<usize> = test.y.iter().map(|&y| y.min(1)).collect();
    assert!(metrics::accuracy(&preds, &truth) > 0.9);
}

#[test]
fn fit_is_bit_identical_to_the_deprecated_wrappers() {
    // The API collapse must be free: `Engine::fit` with the matching
    // `TrainOptions` reproduces each historical entry point bit for
    // bit, because both call the same internal body.
    let e = engine();
    let net = apps::network("iris_class").unwrap();
    let ds = datasets::iris(0);
    let xs = ds.rows();
    // per-sample stochastic BP (train ≡ fit with defaults)
    let (p_old, r_old) = e
        .train(net, &xs, |i| ds.target(i, 1), 3, 1.0, 9)
        .unwrap();
    let run = e
        .fit(net, &xs, |i| ds.target(i, 1), 3, 1.0, 9,
             &TrainOptions::new())
        .unwrap();
    assert_eq!(run.reports.len(), 1);
    assert_eq!(r_old.loss_curve, run.last_report().unwrap().loss_curve);
    for (a, b) in p_old.iter().zip(&run.params) {
        assert_eq!(a.data, b.data);
    }
    // mini-batch accumulation (train_with ≡ fit with .batch(n))
    let (p_old, r_old) = e
        .train_with(net, &xs, |i| ds.target(i, 1), 3, 0.5, 9, 8)
        .unwrap();
    let run = e
        .fit(net, &xs, |i| ds.target(i, 1), 3, 0.5, 9,
             &TrainOptions::new().batch(8))
        .unwrap();
    assert_eq!(run.last_report().unwrap().batch, 8);
    assert_eq!(r_old.loss_curve, run.last_report().unwrap().loss_curve);
    for (a, b) in p_old.iter().zip(&run.params) {
        assert_eq!(a.data, b.data);
    }
    // staged dimensionality reduction (train_dr ≡ fit with .dr())
    let dr = apps::network("mnist_dr").unwrap();
    let mut rng = restream::testing::Rng::seeded(17);
    let xs_dr: Vec<Vec<f32>> = (0..12)
        .map(|_| rng.vec_uniform(dr.layers[0], -0.5, 0.5))
        .collect();
    let (p_old, r_old) = e.train_dr(dr, &xs_dr, 1, 0.5, 9, 4).unwrap();
    let run = e
        .fit(dr, &xs_dr, |_| Vec::new(), 1, 0.5, 9,
             &TrainOptions::new().batch(4).dr())
        .unwrap();
    assert_eq!(r_old.len(), run.reports.len());
    for (a, b) in r_old.iter().zip(&run.reports) {
        assert_eq!(a.loss_curve, b.loss_curve);
    }
    for (a, b) in p_old.iter().zip(&run.params) {
        assert_eq!(a.data, b.data);
    }
}

#[test]
fn training_is_deterministic_for_a_seed() {
    let e = engine();
    let net = apps::network("iris_class").unwrap();
    let ds = datasets::iris(0);
    let xs = ds.rows();
    let run = || {
        let (p, r) = e
            .train(net, &xs, |i| ds.target(i, 1), 2, 1.0, 9)
            .unwrap();
        (p[0].data.clone(), r.loss_curve)
    };
    let (p1, c1) = run();
    let (p2, c2) = run();
    assert_eq!(c1, c2);
    assert_eq!(p1, p2);
}
