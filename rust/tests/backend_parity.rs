//! Parity: the backend kernel entry points against golden values
//! generated from the Python oracle `python/compile/kernels/ref.py`.
//!
//! The goldens were produced by `python/tests/gen_parity_goldens.py`,
//! which ports the crate's deterministic xoshiro256++ PRNG
//! (`testing/rng.rs`) to Python bit-for-bit, draws the exact input
//! tensors the functions below draw, runs them through the jnp
//! reference kernels and emits the expected outputs as Rust literals.
//! The generator also asserts that no quantised output sits near a
//! rounding boundary, so the sequential f32 accumulation used here and
//! jax's matmul ordering cannot land on different ADC codes — which is
//! why the quantised outputs are compared to 1e-6 while raw dot
//! products get a 1e-5 association tolerance.
//!
//! Regenerate after changing the kernel semantics or `hwspec`:
//!
//! ```text
//! cd python && python -m tests.gen_parity_goldens
//! ```

use restream::config::hwspec as hw;
use restream::runtime::{ArrayF32, Backend, NativeBackend};
use restream::testing::Rng;

const SEED: u64 = 2024;
const B: usize = 4;
const N_IN: usize = 6; // includes the bias row
const N_OUT: usize = 5;
const K: usize = 4;
const D: usize = 3;
const KB: usize = 8;
const LR: f32 = 0.7;

// ---- goldens emitted by gen_parity_goldens.py (jax 0.4, f32) ----
const GOLD_Y: [f32; 20] = [-0.0714285671710968, 0.07142859697341919, 0.07142859697341919, 0.07142859697341919, -0.0714285671710968, -0.0714285671710968, -0.0714285671710968, 0.07142859697341919, 0.07142859697341919, -0.0714285671710968, -0.0714285671710968, -0.0714285671710968, -0.0714285671710968, 0.07142859697341919, 0.07142859697341919, 0.07142859697341919, -0.0714285671710968, -0.0714285671710968, -0.0714285671710968, 0.07142859697341919];
const GOLD_DP: [f32; 20] = [-0.2624503970146179, 0.09650944918394089, 0.02272646129131317, 0.2513033151626587, -0.12284677475690842, -0.051000453531742096, -0.3136220872402191, 0.3418852686882019, 0.2486523687839508, -0.2627072334289551, -0.12349622696638107, -0.2979294955730438, -0.11712302267551422, 0.15658655762672424, 0.1770646572113037, 0.14846870303153992, -0.2322009950876236, -0.069297656416893, -0.1405046582221985, 0.186963751912117];
const GOLD_BWD: [f32; 24] = [-0.5118110179901123, 0.5354330539703369, 0.29133859276771545, -0.9055117964744568, -0.25984251499176025, 0.20472441613674164, -0.25984251499176025, -0.8503937125205994, 0.4724409580230713, 1.0, 1.0, 0.3779527544975281, -0.8897637724876404, 1.0, -0.4094488322734833, -0.4488188922405243, 0.13385826349258423, 0.8976377844810486, -0.17322835326194763, 0.4803149700164795, -0.19685038924217224, -0.8818897604942322, -0.5511810779571533, 0.23622047901153564];
const GOLD_GP2: [f32; 30] = [0.4408217966556549, 0.39442527294158936, 0.0010000000474974513, 0.5242193937301636, 0.4411214292049408, 0.7434695959091187, 0.6294616460800171, 0.6388708353042603, 0.6788120865821838, 0.4589642286300659, 0.9606812000274658, 0.6218668818473816, 0.12138433754444122, 0.2525075674057007, 0.4889800548553467, 0.031550344079732895, 0.2825995683670044, 0.17920807003974915, 0.7827224731445312, 0.8794811964035034, 0.123059943318367, 0.9935970306396484, 0.2813379168510437, 0.6259129643440247, 0.3136519193649292, 0.502348005771637, 0.5701189637184143, 0.13115668296813965, 0.9527504444122314, 0.14675471186637878];
const GOLD_GN2: [f32; 30] = [0.6373817920684814, 0.8289368152618408, 0.02280595153570175, 0.6964234709739685, 0.17401795089244843, 0.03617499768733978, 0.8015880584716797, 0.3579244613647461, 0.4261658787727356, 0.9965477585792542, 0.748892068862915, 0.11931626498699188, 0.7275428175926208, 0.9811822772026062, 0.2148296982049942, 0.34568360447883606, 0.3650948703289032, 0.944614052772522, 0.24760441482067108, 0.6828365325927734, 0.3023926317691803, 0.6916321516036987, 0.769309401512146, 0.1580580323934555, 0.16426819562911987, 0.6387221217155457, 0.016005726531147957, 0.22659794986248016, 0.7306063771247864, 0.6016399264335632];
const GOLD_ASSIGN: [f32; 8] = [3.0, 2.0, 2.0, 1.0, 3.0, 3.0, 1.0, 1.0];
const GOLD_ACC: [f32; 12] = [0.0, 0.0, 0.0, 1.1063549518585205, 0.5857268571853638, -1.1040096282958984, -0.48822250962257385, 0.38487011194229126, 0.45670315623283386, -0.16636203229427338, -0.04245464503765106, -0.43330565094947815];
const GOLD_COUNTS: [f32; 4] = [0.0, 3.0, 2.0, 3.0];

/// The shared input tensors, drawn in the exact order (and with the
/// exact sampling calls) `gen_parity_goldens.py` draws them.
struct Inputs {
    x: ArrayF32,
    gp: ArrayF32,
    gn: ArrayF32,
    delta: ArrayF32,
    kx: ArrayF32,
    kc: ArrayF32,
}

fn inputs() -> Inputs {
    let mut rng = Rng::seeded(SEED);
    let x = ArrayF32::matrix(B, N_IN, rng.vec_uniform(B * N_IN, -0.5, 0.5))
        .unwrap();
    let gp = ArrayF32::matrix(
        N_IN,
        N_OUT,
        rng.vec_uniform(N_IN * N_OUT, 0.001, 1.0),
    )
    .unwrap();
    let gn = ArrayF32::matrix(
        N_IN,
        N_OUT,
        rng.vec_uniform(N_IN * N_OUT, 0.001, 1.0),
    )
    .unwrap();
    let delta =
        ArrayF32::matrix(B, N_OUT, rng.vec_uniform(B * N_OUT, -1.0, 1.0))
            .unwrap();
    let kx = ArrayF32::matrix(KB, D, rng.vec_uniform(KB * D, -0.5, 0.5))
        .unwrap();
    let kc = ArrayF32::matrix(K, D, rng.vec_uniform(K * D, -0.5, 0.5))
        .unwrap();
    Inputs { x, gp, gn, delta, kx, kc }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol,
            "{what}[{i}]: got {g}, golden {w} (tol {tol})"
        );
    }
}

#[test]
fn forward_matches_ref_py_goldens() {
    let inp = inputs();
    let b = NativeBackend;
    let (y, dp) =
        b.forward(&inp.x, &inp.gp, &inp.gn, hw::OUT_BITS).unwrap();
    assert_eq!(y.shape, vec![B, N_OUT]);
    // quantised outputs land on the exact ADC codes of the oracle
    assert_close(&y.data, &GOLD_Y, 1e-6, "y");
    // raw dot products: f32 association tolerance vs jax matmul
    assert_close(&dp.data, &GOLD_DP, 1e-5, "dp");
}

#[test]
fn backward_matches_ref_py_goldens() {
    let inp = inputs();
    let b = NativeBackend;
    let back = b.backward(&inp.delta, &inp.gp, &inp.gn).unwrap();
    assert_eq!(back.shape, vec![B, N_IN]);
    assert_close(&back.data, &GOLD_BWD, 1e-6, "bwd");
}

#[test]
fn weight_update_matches_ref_py_goldens() {
    let inp = inputs();
    let b = NativeBackend;
    let (_, dp) =
        b.forward(&inp.x, &inp.gp, &inp.gn, hw::OUT_BITS).unwrap();
    let (gp2, gn2) = b
        .weight_update(&inp.gp, &inp.gn, &inp.x, &inp.delta, &dp, LR)
        .unwrap();
    assert_close(&gp2.data, &GOLD_GP2, 1e-5, "gp'");
    assert_close(&gn2.data, &GOLD_GN2, 1e-5, "gn'");
    // conductances stay inside the device range
    for g in gp2.data.iter().chain(&gn2.data) {
        assert!((hw::G_MIN..=hw::G_MAX).contains(g));
    }
}

#[test]
fn kmeans_step_matches_ref_py_goldens() {
    let inp = inputs();
    let b = NativeBackend;
    let step = b.kmeans_step(&inp.kx, &inp.kc).unwrap();
    assert_eq!(step.k, K);
    assert_eq!(step.dims, D);
    for (i, want) in GOLD_ASSIGN.iter().enumerate() {
        assert_eq!(step.assign[i], *want as usize, "assign[{i}]");
    }
    assert_close(&step.acc, &GOLD_ACC, 1e-5, "acc");
    assert_close(&step.counts, &GOLD_COUNTS, 0.0, "counts");
}
