//! Multi-tenant determinism: co-residency must be a pure scheduling
//! change. Every app served from a shared `ChipScheduler` returns
//! **bit-identical** outputs to a dedicated single-app `Server` over
//! the same network and parameters — no matter how many apps share the
//! chip, how many clients race each app's queue, how many workers the
//! shared pool runs, or whether the schedule forced reconfiguration
//! swaps (swaps move mesh residency, never numerics).
//!
//! Pinned per the acceptance criteria across clients ∈ {1, 4} ×
//! workers ∈ {1, 4} on three co-resident apps, plus a forced-swap
//! schedule on a 4-core chip, plus the admission error for a resident
//! set exceeding the 144-core mesh.

use std::time::Duration;

use restream::chip::{plan_residency, ChipApp, ChipConfig, ChipScheduler};
use restream::config::{apps, Network, SystemConfig};
use restream::coordinator::{init_conductances, Engine};
use restream::runtime::ArrayF32;
use restream::serve::{ServeConfig, Server};
use restream::testing::Rng;

const APPS: [&str; 3] = ["iris_ae", "iris_class", "kdd_ae"];
const SAMPLES: usize = 32;

struct Fixture {
    net: Network,
    params: Vec<ArrayF32>,
    xs: Vec<Vec<f32>>,
    /// What a dedicated single-app `Server` answers for each sample.
    expect: Vec<Vec<f32>>,
}

/// Serve `xs` one by one through a dedicated single-app server — the
/// reference the shared scheduler must match bit for bit.
fn dedicated_outputs(
    net: &Network,
    params: &[ArrayF32],
    xs: &[Vec<f32>],
) -> Vec<Vec<f32>> {
    let server = Server::start(
        Engine::native(),
        net.clone(),
        params.to_vec(),
        ServeConfig::default(),
    );
    let client = server.client();
    let outs: Vec<Vec<f32>> =
        xs.iter().map(|x| client.call(x.clone()).unwrap().out).collect();
    drop(client);
    server.shutdown();
    outs
}

fn fixture(app: &str) -> Fixture {
    let net = apps::network(app).unwrap().clone();
    let params = init_conductances(net.layers, 7);
    let mut rng = Rng::seeded(0xC41F ^ net.layers[0] as u64);
    let xs: Vec<Vec<f32>> = (0..SAMPLES)
        .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
        .collect();
    let expect = dedicated_outputs(&net, &params, &xs);
    Fixture { net, params, xs, expect }
}

fn hosted(fixtures: &[Fixture]) -> Vec<ChipApp> {
    fixtures
        .iter()
        .map(|f| ChipApp { net: f.net.clone(), params: f.params.clone() })
        .collect()
}

#[test]
fn shared_chip_matches_dedicated_servers() {
    let fixtures: Vec<Fixture> = APPS.iter().map(|a| fixture(a)).collect();
    for &workers in &[1usize, 4] {
        for &clients in &[1usize, 4] {
            let chip = ChipScheduler::start(
                Engine::native().with_workers(workers),
                hosted(&fixtures),
                ChipConfig {
                    max_wait: Duration::from_millis(2),
                    ..ChipConfig::default()
                },
            )
            .unwrap();
            // All apps hammered concurrently: `clients` threads per
            // app, each owning a contiguous slice of that app's
            // samples (so outputs are indexable afterwards).
            let mut handles = Vec::new();
            for (a, f) in fixtures.iter().enumerate() {
                let per = f.xs.len() / clients;
                for c in 0..clients {
                    let client = chip.client(APPS[a]).unwrap();
                    let lo = c * per;
                    let hi = if c + 1 == clients {
                        f.xs.len()
                    } else {
                        lo + per
                    };
                    let mine: Vec<(usize, Vec<f32>)> =
                        (lo..hi).map(|i| (i, f.xs[i].clone())).collect();
                    handles.push(std::thread::spawn(move || {
                        let outs: Vec<(usize, Vec<f32>)> = mine
                            .into_iter()
                            .map(|(i, x)| {
                                (i, client.call(x).unwrap().out)
                            })
                            .collect();
                        (a, outs)
                    }));
                }
            }
            for handle in handles {
                let (a, outs) = handle.join().unwrap();
                for (i, out) in outs {
                    assert_eq!(
                        fixtures[a].expect[i], out,
                        "{}: sample {i} diverged at clients={clients}, \
                         workers={workers}",
                        APPS[a]
                    );
                }
            }
            let report = chip.shutdown();
            assert_eq!(report.total_errors(), 0);
            assert_eq!(report.total_requests(), 3 * SAMPLES);
            for (a, app_report) in report.apps.iter().enumerate() {
                assert_eq!(app_report.app, APPS[a]);
                assert_eq!(app_report.serve.requests, SAMPLES);
            }
            // 6 cores across three 2-core apps: everyone stays
            // resident on the 144-core chip — no swaps ever
            assert_eq!(report.swaps, 0, "unexpected swaps");
            assert!(report.apps.iter().all(|a| a.resident));
            assert!(report.occupancy_pct > 0.0);
        }
    }
}

#[test]
fn forced_swaps_stay_bit_identical() {
    // A 4-core chip can hold only two of the three 2-core apps at a
    // time; round-robin requests force eviction ping-pong. Outputs
    // must still match the dedicated servers bit for bit — the
    // reconfiguration is modeled (charged), not numeric.
    let fixtures: Vec<Fixture> = APPS.iter().map(|a| fixture(a)).collect();
    let chip = ChipScheduler::start(
        Engine::native(),
        hosted(&fixtures),
        ChipConfig {
            sys: SystemConfig { neural_cores: 4, ..Default::default() },
            max_wait: Duration::ZERO,
            ..ChipConfig::default()
        },
    )
    .unwrap();
    let clients: Vec<_> =
        APPS.iter().map(|a| chip.client(a).unwrap()).collect();
    for i in 0..SAMPLES {
        for (a, f) in fixtures.iter().enumerate() {
            let out = clients[a].call(f.xs[i].clone()).unwrap().out;
            assert_eq!(
                f.expect[i], out,
                "{}: sample {i} diverged under forced swapping",
                APPS[a]
            );
        }
    }
    drop(clients);
    let report = chip.shutdown();
    assert_eq!(report.total_errors(), 0);
    assert!(report.swaps >= 1, "schedule did not force a swap");
    assert!(report.evictions >= 1);
    assert!(
        report.reconfig_total_s > 0.0,
        "swaps must charge reconfiguration time"
    );
    // at most two of the three apps can end resident on 4 cores
    let resident = report.apps.iter().filter(|a| a.resident).count();
    assert!(resident <= 2, "{resident} residents on a 4-core chip");
}

#[test]
fn admission_rejects_sets_exceeding_the_mesh() {
    // isolet_class (~130 cores) + mnist_class (~13) + kdd_ae (2)
    // oversubscribes the 144-core mesh.
    let sys = SystemConfig::default();
    let names = ["isolet_class", "mnist_class", "kdd_ae"];
    let nets: Vec<&Network> =
        names.iter().map(|n| apps::network(n).unwrap()).collect();
    let demand: usize = nets
        .iter()
        .map(|n| restream::chip::footprint(n, &sys).unwrap().cores)
        .sum();
    assert!(demand > 144, "fixture no longer oversubscribes: {demand}");
    let err = plan_residency(&nets, &sys).unwrap_err();
    assert!(err.contains("144"), "{err}");
    assert!(err.contains("isolet_class"), "{err}");
    assert!(err.contains("drop an app"), "{err}");
    // the scheduler surface enforces the same check up front
    let hosted: Vec<ChipApp> = nets
        .iter()
        .map(|n| ChipApp {
            net: (*n).clone(),
            params: init_conductances(n.layers, 0),
        })
        .collect();
    let err = ChipScheduler::start(
        Engine::native(),
        hosted,
        ChipConfig { require_resident: true, ..ChipConfig::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("144"), "{err}");
}
