//! Cross-mode equivalence: the layer-pipelined engine must be a pure
//! scheduling change. Pipeline ≡ data-parallel ≡ sequential,
//! **bitwise**, on every registered application (the DR encoder
//! stacks and the deep mnist_class included), across workers
//! {1, 2, 4} and multiple stage counts — all through the reusable
//! [`ExecModeHarness`](restream::testing::ExecModeHarness), so future
//! backends and exec modes inherit the same coverage.
//!
//! Why this holds by construction: chunk boundaries are a pure
//! function of `(n_samples, tile)`, stage boundaries of
//! `(n_layers, stages)`, inter-stage queues are in-order FIFOs, and
//! the per-stage math is the exact clip/bias/crossbar composition of
//! the fused forward (see `coordinator::pipeline` and DESIGN.md
//! "Pipelined execution").

use restream::config::apps;
use restream::coordinator::{
    init_conductances, Engine, ExecMode, TrainOptions,
};
use restream::testing::{ExecModeHarness, Rng};

fn rows(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

#[test]
fn every_app_is_bit_identical_across_exec_modes() {
    let harness = ExecModeHarness::new();
    assert_eq!(harness.workers, vec![1, 2, 4]);
    assert!(harness.stages.len() >= 2, "acceptance: >= 2 stage counts");
    for net in apps::NETWORKS {
        // enough samples to cross a tile boundary; fewer for the big
        // ISOLET stacks to keep debug-mode test time sane
        let n = if net.layers[0] > 500 { 33 } else { 130 };
        let mut rng = Rng::seeded(0xC0DE ^ net.layers[0] as u64);
        let xs = rows(&mut rng, n, net.layers[0]);
        let params = init_conductances(net.layers, 7);
        harness.assert_bit_identical(net, &params, &xs);
    }
}

#[test]
fn custom_sweeps_cover_degenerate_stage_counts() {
    // 1 stage (the whole net on one stage) and more stages than layers
    // (clamped) must behave exactly like the defaults.
    let harness = ExecModeHarness {
        workers: vec![1, 3],
        stages: vec![1, 9],
    };
    let net = apps::network("mnist_class").unwrap();
    let mut rng = Rng::seeded(31);
    let xs = rows(&mut rng, 70, net.layers[0]);
    let params = init_conductances(net.layers, 3);
    harness.assert_bit_identical(net, &params, &xs);
}

#[test]
fn dr_training_is_bit_identical_across_exec_modes() {
    // The DR pipeline's inter-stage re-encodes follow the exec mode;
    // trained encoder stacks must not care.
    let net = apps::network("mnist_dr").unwrap();
    let mut rng = Rng::seeded(77);
    let xs = rows(&mut rng, 12, net.layers[0]);
    let fit = |exec: ExecMode, workers: usize| {
        let engine = Engine::native().with_workers(workers);
        let opts = TrainOptions::new().dr().exec(exec);
        engine
            .fit(net, &xs, |_| Vec::new(), 1, 0.05, 5, &opts)
            .unwrap()
    };
    let reference = fit(ExecMode::DataParallel, 1);
    for exec in [ExecMode::Pipelined, ExecMode::Hybrid] {
        for workers in [1, 2, 4] {
            let run = fit(exec, workers);
            assert_eq!(
                run.params.len(),
                reference.params.len(),
                "{exec} workers={workers}"
            );
            for (a, b) in run.params.iter().zip(&reference.params) {
                assert_eq!(a.data, b.data, "{exec} workers={workers}");
            }
        }
    }
}

#[test]
fn pipeline_reports_expose_per_stage_occupancy() {
    let net = apps::network("mnist_class").unwrap();
    let mut rng = Rng::seeded(3);
    let xs = rows(&mut rng, 70, net.layers[0]);
    let params = init_conductances(net.layers, 7);
    let engine = Engine::native()
        .with_exec(ExecMode::Pipelined)
        .with_pipeline_stages(4);
    engine.infer(net, &params, &xs).unwrap();
    let report = engine.last_pipeline_report().expect("report recorded");
    assert_eq!(report.stages.len(), 4);
    assert_eq!(report.samples, 70);
    assert_eq!(report.replicas, 1);
    // 70 samples = 2 chunks of the 64-sample tile, through every stage
    assert!(report.stages.iter().all(|s| s.chunks == 2));
    for s in &report.stages {
        let occ = s.occupancy();
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
    }
    assert!(report.throughput() > 0.0);
    assert!(report.summary().contains("stage 0"));
}
