//! Parallel determinism: the sharded execution layer must be a pure
//! scheduling change. `infer`, `kmeans` and `anomaly_scores` results
//! are **bit-identical** across worker counts {1, 2, 4, 7} on every
//! registered application — shard boundaries are fixed by the plan
//! (never the pool size) and partials reduce left-to-right on one
//! thread (see `coordinator::pool` for the contract).

use restream::config::apps;
use restream::coordinator::{init_conductances, Engine};
use restream::testing::{forall, Rng};

/// Worker counts swept everywhere below; 7 is deliberately coprime
/// with the 64-sample tile and every shard-hint value.
const SWEEP: [usize; 3] = [2, 4, 7];

fn rows(rng: &mut Rng, n: usize, dims: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| rng.vec_uniform(dims, -0.5, 0.5)).collect()
}

#[test]
fn infer_is_bit_identical_across_worker_counts_on_all_apps() {
    for net in apps::NETWORKS {
        // enough samples to cross a tile boundary; fewer for the big
        // ISOLET stacks to keep debug-mode test time sane
        let n = if net.layers[0] > 500 { 65 } else { 130 };
        let mut rng = Rng::seeded(0xC0DE ^ net.layers[0] as u64);
        let xs = rows(&mut rng, n, net.layers[0]);
        let params = init_conductances(net.layers, 7);
        let reference = Engine::native()
            .with_workers(1)
            .infer(net, &params, &xs)
            .unwrap();
        assert_eq!(reference.len(), n, "{}", net.name);
        for &w in &SWEEP {
            let out = Engine::native()
                .with_workers(w)
                .infer(net, &params, &xs)
                .unwrap();
            assert_eq!(reference, out, "{} at {w} workers", net.name);
        }
    }
}

#[test]
fn kmeans_is_bit_identical_across_worker_counts_on_all_apps() {
    for app in apps::KMEANS_APPS {
        let mut rng = Rng::seeded(0x5EED ^ app.clusters as u64);
        let xs = rows(&mut rng, 300, app.dims); // 5 tiles (last short)
        let (ref_centres, ref_assign) = Engine::native()
            .with_workers(1)
            .kmeans(app, &xs, 4, 3)
            .unwrap();
        for &w in &SWEEP {
            let (centres, assign) = Engine::native()
                .with_workers(w)
                .kmeans(app, &xs, 4, 3)
                .unwrap();
            assert_eq!(ref_centres, centres, "{} at {w} workers", app.name);
            assert_eq!(ref_assign, assign, "{} at {w} workers", app.name);
        }
    }
}

#[test]
fn anomaly_scores_are_bit_identical_across_worker_counts() {
    for name in ["kdd_ae", "iris_ae"] {
        let net = apps::network(name).unwrap();
        let mut rng = Rng::seeded(0xA0A ^ net.layers[0] as u64);
        let xs = rows(&mut rng, 200, net.layers[0]);
        let params = init_conductances(net.layers, 11);
        let reference = Engine::native()
            .with_workers(1)
            .anomaly_scores(net, &params, &xs)
            .unwrap();
        for &w in &SWEEP {
            let scores = Engine::native()
                .with_workers(w)
                .anomaly_scores(net, &params, &xs)
                .unwrap();
            // f64 scores: compare to the bit
            assert_eq!(reference, scores, "{name} at {w} workers");
        }
    }
}

#[test]
fn randomized_batch_sizes_stay_deterministic() {
    // Random batch lengths (including < 1 tile and ragged tails) and
    // random worker pairs on the cheap apps; one reusable engine per
    // worker count to also cover pool reuse across operations.
    let net = apps::network("kdd_ae").unwrap();
    let app = apps::kmeans_app("mnist_kmeans").unwrap();
    forall("parallel_determinism", 10, |rng| {
        let n = rng.range(1, 220);
        let seed = rng.next_u64();
        let xs: Vec<Vec<f32>> = (0..n)
            .map(|_| rng.vec_uniform(net.layers[0], -0.5, 0.5))
            .collect();
        let params = init_conductances(net.layers, seed);
        let wa = SWEEP[rng.below(SWEEP.len())];
        let ea = Engine::native().with_workers(wa);
        let e1 = Engine::native().with_workers(1);
        let a = ea.infer(net, &params, &xs).map_err(|e| e.to_string())?;
        let b = e1.infer(net, &params, &xs).map_err(|e| e.to_string())?;
        if a != b {
            return Err(format!("infer diverged at {wa} workers, n={n}"));
        }
        let sa = ea
            .anomaly_scores(net, &params, &xs)
            .map_err(|e| e.to_string())?;
        let sb = e1
            .anomaly_scores(net, &params, &xs)
            .map_err(|e| e.to_string())?;
        if sa != sb {
            return Err(format!("anomaly diverged at {wa} workers, n={n}"));
        }
        // at least `clusters` samples so centre seeding succeeds
        let km = rng.range(app.clusters, 150);
        let kxs: Vec<Vec<f32>> = (0..km)
            .map(|_| rng.vec_uniform(app.dims, -0.5, 0.5))
            .collect();
        let ka = ea.kmeans(app, &kxs, 3, seed).map_err(|e| e.to_string())?;
        let kb = e1.kmeans(app, &kxs, 3, seed).map_err(|e| e.to_string())?;
        if ka != kb {
            return Err(format!("kmeans diverged at {wa} workers, n={km}"));
        }
        Ok(())
    });
}
