//! Integration: mapper → placement → NoC schedule → cost model, across
//! every Table I application — the architecture-side contract.

use restream::config::{apps, SystemConfig};
use restream::mapper::{map_network, place};
use restream::noc::Schedule;
use restream::{report, sim};

#[test]
fn every_network_maps_places_and_schedules() {
    let sys = SystemConfig::default();
    for net in apps::NETWORKS {
        let map = map_network(net, &sys)
            .unwrap_or_else(|e| panic!("{}: {e}", net.name));
        assert!(map.cores_used() <= sys.neural_cores, "{}", net.name);
        for stage in &map.stages {
            let placement = place(stage, &sys);
            // every placed core on the mesh
            for row in &placement.coords {
                for &(x, y) in row {
                    assert!(x < sys.mesh_w && y < sys.mesh_h);
                }
            }
            // both traffic directions schedule conflict-free
            for transfers in [&placement.fwd_transfers, &placement.bwd_transfers] {
                let sched = Schedule::build(transfers, sys.link_bits);
                sched.validate().unwrap_or_else(|l| {
                    panic!("{} stage {}: link {l:?}", net.name, stage.name)
                });
            }
        }
    }
}

#[test]
fn tables_3_and_4_cover_all_apps_with_positive_costs() {
    let sys = SystemConfig::default();
    for rows in [sim::table3(&sys), sim::table4(&sys)] {
        assert_eq!(rows.len(), 7);
        for r in rows {
            assert!(r.time_s > 0.0, "{}", r.app);
            assert!(r.total_j > 0.0, "{}", r.app);
            assert!(r.total_j >= r.compute_j + r.io_j - 1e-18);
            assert!(r.cores >= 1);
        }
    }
}

#[test]
fn headline_claims_hold_in_shape() {
    // Paper abstract: "up to 30x (training) / 50x (recognition) speedup,
    // four to six orders of magnitude more energy efficiency".
    let sys = SystemConfig::default();
    let train = report::vs_gpu(&sys, true);
    let recog = report::vs_gpu(&sys, false);
    let net_apps = |v: &[report::VsGpu]| -> Vec<report::VsGpu> {
        v.iter()
            .filter(|s| apps::network(&s.app).is_some())
            .cloned()
            .collect()
    };
    // every app wins on both axes
    for s in train.iter().chain(&recog) {
        assert!(s.speedup > 1.0, "{} speedup {}", s.app, s.speedup);
        assert!(s.energy_eff > 1.0, "{}", s.app);
    }
    // energy efficiency of the neural apps sits in the 10^4..10^7 band
    for s in net_apps(&train).iter().chain(&net_apps(&recog)) {
        assert!(
            s.energy_eff > 1e4 && s.energy_eff < 1e8,
            "{}: {:.2e}",
            s.app,
            s.energy_eff
        );
    }
    // recognition speedups exceed training speedups on average (paper:
    // 50x vs 30x) — weights never move, so inference profits most
    let mean = |v: &[report::VsGpu]| {
        v.iter().map(|s| s.speedup).sum::<f64>() / v.len() as f64
    };
    assert!(mean(&net_apps(&recog)) > 0.5 * mean(&net_apps(&train)));
}

#[test]
fn chip_reconfigures_within_a_millisecond() {
    // Section II: RISC core configures cores, switches, DMA, then gates
    // off. The config phase must be negligible next to an epoch.
    use restream::cores::risc::ConfigWork;
    use restream::cores::RiscCore;
    let sys = SystemConfig::default();
    let net = apps::network("isolet_class").unwrap();
    let map = map_network(net, &sys).unwrap();
    let work = ConfigWork {
        neural_cores: map.cores_used(),
        routers: sys.mesh_w * sys.mesh_h + 2,
        switch_bits: (sys.mesh_w * sys.mesh_h + 2) * 64 * 25,
        dma_descriptors: 8,
    };
    let risc = RiscCore::default();
    assert!(risc.config_time_s(&work) < 1e-3);
    assert_eq!(risc.steady_power_w(), 0.0);
}
