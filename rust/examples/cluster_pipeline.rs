//! The paper's headline unsupervised pipeline (section II): autoencoder
//! dimensionality reduction on the memristor cores feeding k-means on
//! the digital clustering core.
//!
//! MNIST-like 784-dim data → layerwise-pretrained 784→…→20 encoder →
//! 20-dim codes → k-means (k = 10) on the clustering core → purity.
//!
//! ```bash
//! cargo run --release --example cluster_pipeline
//! ```

use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions};
use restream::{datasets, metrics};

fn main() -> anyhow::Result<()> {
    let dr = apps::network("mnist_dr").unwrap();
    let km = apps::kmeans_app("mnist_kmeans").unwrap();
    let engine = Engine::open_default()?;

    let ds = datasets::mnist(512, 1);
    let xs = ds.rows();

    // Stage-by-stage AE pre-training (chip reconfigured between stages).
    println!("layerwise pre-training {} ({} stages)…",
             dr.name, dr.layers.len() - 1);
    // batch 1: the paper's per-sample stochastic BP (add .batch(N) for
    // data-parallel mini-batch pre-training over the worker pool)
    let run = engine.fit(
        dr, &xs, |_| Vec::new(), 1, 0.6, 0,
        &TrainOptions::new().dr(),
    )?;
    let (encoder, reports) = (&run.params, &run.reports);
    for (s, r) in reports.iter().enumerate() {
        println!(
            "  stage {s}: loss {:.4} ({} samples, {:.1}s)",
            r.loss_curve.last().unwrap(),
            r.samples_seen,
            r.wall_s
        );
    }

    // Encode through the full encoder stack (the DR forward graph).
    let codes = engine.encode(dr, encoder, &xs)?;
    println!("encoded {} samples to {} dims", codes.len(), codes[0].len());

    // Cluster the codes on the digital clustering core model.
    let (_, assign) = engine.kmeans(km, &codes, 12, 0)?;
    let purity = metrics::purity(&assign, &ds.y, km.clusters, ds.classes);
    println!("k-means purity over AE codes: {purity:.3}");

    // Baseline: cluster the raw 784-dim pixels with the Rust reference
    // k-means (what the chip avoids by reducing dimensionality first).
    let mut rng = restream::testing::Rng::seeded(0);
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    let mut raw = restream::kmeans::KMeans::init(&flat, xs.len(), 784, 10, &mut rng);
    let (raw_assign, _) = raw.fit(&flat, xs.len(), 12, 1e-5);
    let raw_purity = metrics::purity(&raw_assign, &ds.y, 10, ds.classes);
    println!("k-means purity on raw pixels:  {raw_purity:.3}");
    println!(
        "(the clustering core cannot even hold 784 dims — max {} — \
         which is the paper's point)",
        restream::config::hwspec::KMEANS_MAX_DIM
    );
    Ok(())
}
