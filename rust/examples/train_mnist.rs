//! End-to-end driver: train the paper's MNIST classifier
//! (784→300→200→100→10, Table I) on the full three-layer stack for a
//! few hundred steps and log the loss curve — the repository's
//! whole-system proof that reference kernels → training graph →
//! backend → streaming coordinator compose.
//!
//! Uses mini-batched training (b=16): each step is one backend
//! `train_step` call over 16 samples of gradient accumulation — on the
//! native backend a batched in-process loop, on the PJRT backend
//! (`--features pjrt` + `make artifacts`, `RESTREAM_BACKEND=pjrt`) one
//! XLA execution of the `mnist_class_train_b16` artifact. Results are
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example train_mnist [steps]
//! ```

use anyhow::anyhow;
use restream::config::{apps, SystemConfig};
use restream::coordinator::{init_conductances, Engine};
use restream::runtime::ArrayF32;
use restream::{datasets, gpu, metrics, sim};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300)
        .max(1);
    let batch = apps::BIG_TRAIN_BATCH;
    let net = apps::network("mnist_class").unwrap();
    let sys = SystemConfig::default();

    // synthetic MNIST (784-dim, 10 classes; see DESIGN.md substitutions)
    let ds = datasets::mnist(2048, 0);
    let (train, test) = ds.split(0.85, 0);

    let engine = Engine::open_default()?;
    let backend = engine.backend();
    println!(
        "training {} on {} samples, batch {batch}, {steps} steps \
         ({} backend)",
        net.name,
        train.len(),
        backend.name()
    );

    let graph = format!("mnist_class_train_b{batch}");
    let mut params = init_conductances(net.layers, 0);

    let start = std::time::Instant::now();
    let mut curve = Vec::new();
    for step in 0..steps {
        // next batch (wrapping over the training set)
        let mut xb = Vec::with_capacity(batch * 784);
        let mut tb = Vec::with_capacity(batch * 10);
        for k in 0..batch {
            let i = (step * batch + k) % train.len();
            xb.extend_from_slice(train.sample(i));
            tb.extend_from_slice(&train.target(i, 10));
        }
        let xs = ArrayF32::matrix(batch, 784, xb).map_err(|e| anyhow!(e))?;
        let ts = ArrayF32::matrix(batch, 10, tb).map_err(|e| anyhow!(e))?;
        let (next, loss) =
            backend.train_step(&graph, params, &xs, &ts, 0.25)?;
        params = next;
        curve.push(loss);
        if step % 25 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.5}");
        }
    }
    let wall = start.elapsed().as_secs_f64();
    println!(
        "\n{} steps ({} samples) in {wall:.1}s = {:.1} samples/s",
        steps,
        steps * batch,
        (steps * batch) as f64 / wall
    );
    let w = curve.len().min(5).max(1);
    let window_mean = |s: &[f32]| {
        metrics::mean(&s.iter().map(|&x| x as f64).collect::<Vec<_>>())
    };
    let first5 = window_mean(&curve[..w]);
    let last5 = window_mean(&curve[curve.len() - w..]);
    println!("loss: first-{w} mean {first5:.4} -> last-{w} mean {last5:.4}");

    // accuracy through the batched recognition graph
    let preds = engine.classify(net, &params, &test.rows())?;
    let acc = metrics::accuracy(&preds, &test.y);
    println!("test accuracy: {acc:.3} (10 classes, chance = 0.100)");

    // chip-model context: what the paper's architecture would do
    let row = sim::train_cost(net, &sys).map_err(anyhow::Error::msg)?;
    let g = gpu::train_cost(net);
    println!(
        "\nchip model: {:.2} us / {:.2e} J per sample on {} cores; \
         K20 baseline {:.1} us -> speedup {:.1}x, energy eff {:.1e}x",
        row.time_s * 1e6,
        row.total_j,
        row.cores,
        g.time_s * 1e6,
        g.time_s / row.time_s,
        g.energy_j / row.total_j
    );
    anyhow::ensure!(last5 < first5 * 0.8, "loss did not fall");
    anyhow::ensure!(acc > 0.5, "accuracy {acc} too low");
    println!("END-TO-END OK");
    Ok(())
}
