//! Anomaly detection on KDD-like traffic (paper section VI.C,
//! Figs 18–20): train the 41→15→41 autoencoder on normal packets only,
//! then threshold reconstruction distance. Prints the two distance
//! histograms and the detection/false-positive sweep.
//!
//! ```bash
//! cargo run --release --example anomaly_kdd
//! ```

use restream::config::apps;
use restream::coordinator::{Engine, TrainOptions};
use restream::{datasets, metrics};

fn bar(n: usize, scale: f64) -> String {
    "#".repeat(((n as f64) * scale).round() as usize)
}

fn main() -> anyhow::Result<()> {
    let net = apps::network("kdd_ae").unwrap();
    let engine = Engine::open_default()?;

    // paper: 5292 normal packets for training (we keep the count; the
    // corpus itself is synthetic — DESIGN.md substitutions)
    let k = datasets::kdd(5292, 600, 600, 0);
    let xs = k.train.rows();
    println!("training {} on {} normal packets", net.name, xs.len());
    let xs_t = xs.clone();
    let run = engine.fit(
        net, &xs, move |i| xs_t[i].clone(), 3, 0.8, 0,
        &TrainOptions::new(),
    )?;
    let rep = run.last_report().unwrap();
    for (e, l) in rep.loss_curve.iter().enumerate() {
        println!("  epoch {e}: recon loss {l:.5}");
    }

    let scores = engine.anomaly_scores(net, &run.params, &k.test.rows())?;
    let normal: Vec<f64> = scores
        .iter()
        .zip(&k.test_attack)
        .filter(|(_, &a)| !a)
        .map(|(s, _)| *s)
        .collect();
    let attack: Vec<f64> = scores
        .iter()
        .zip(&k.test_attack)
        .filter(|(_, &a)| a)
        .map(|(s, _)| *s)
        .collect();
    let hi = scores.iter().cloned().fold(0.0, f64::max);

    println!("\nFig 18 — reconstruction distance, normal packets:");
    for (b, n) in metrics::histogram(&normal, 0.0, hi, 12).iter().enumerate() {
        println!("  [{:>5.2}] {:>4} {}", b as f64 * hi / 12.0, n, bar(*n, 0.2));
    }
    println!("Fig 19 — reconstruction distance, attack packets:");
    for (b, n) in metrics::histogram(&attack, 0.0, hi, 12).iter().enumerate() {
        println!("  [{:>5.2}] {:>4} {}", b as f64 * hi / 12.0, n, bar(*n, 0.2));
    }

    println!("\nFig 20 — detection vs false-positive sweep:");
    let pts = metrics::roc_sweep(&scores, &k.test_attack, 120);
    for p in pts.iter().step_by(12) {
        println!(
            "  thr {:>5.2}: detect {:>5.1}%  false {:>5.1}%",
            p.threshold,
            p.tpr * 100.0,
            p.fpr * 100.0
        );
    }
    println!(
        "\nAUC {:.3}; detection at 4% FPR = {:.1}% (paper: 96.6%)",
        metrics::auc(&pts),
        100.0 * metrics::tpr_at_fpr(&pts, 0.04)
    );
    Ok(())
}
