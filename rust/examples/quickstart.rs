//! Quickstart: train the paper's Iris classifier (Fig 16 workload) on
//! the simulated chip and print the learning curve, the test accuracy,
//! and where the chip's time/energy goes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs on the native backend by default (no artifacts needed); set
//! `RESTREAM_BACKEND=pjrt` with `--features pjrt` + `make artifacts`
//! for the XLA artifact path.

use restream::config::{apps, SystemConfig};
use restream::coordinator::{Engine, TrainOptions};
use restream::{datasets, metrics, report, sim};

fn main() -> anyhow::Result<()> {
    let sys = SystemConfig::default();
    println!("{}", report::chip_summary(&sys));

    // 1. data: the Iris workload of paper section VI.A
    let ds = datasets::iris(0);
    let (train, test) = ds.split(0.8, 0);
    let xs = train.rows();

    // 2. train on-chip: stochastic BP through the memristor constraints,
    //    functionally executed by the selected compute backend
    let net = apps::network("iris_class").unwrap();
    let engine = Engine::open_default()?;
    let run = engine.fit(
        net, &xs, |i| train.target(i, 1), 20, 1.0, 0,
        &TrainOptions::new(),
    )?;
    let (params, rep) = (&run.params, run.last_report().unwrap());
    println!("loss curve (every 4th epoch):");
    for (e, l) in rep.loss_curve.iter().enumerate().step_by(4) {
        println!("  epoch {e:>2}: {l:.4}");
    }

    // 3. evaluate (binary: setosa vs rest — the net has one output)
    let preds = engine.classify(net, params, &test.rows())?;
    let truth: Vec<usize> = test.y.iter().map(|&y| y.min(1)).collect();
    println!("test accuracy: {:.3}", metrics::accuracy(&preds, &truth));

    // 4. what would this cost on the chip? (paper Tables III/IV)
    let t = sim::train_cost(net, &sys).map_err(anyhow::Error::msg)?;
    let r = sim::recognition_cost(net, &sys).map_err(anyhow::Error::msg)?;
    println!(
        "\nchip cost model: train {:.2} us / {:.2e} J per sample; \
         recognition {:.2} us / {:.2e} J",
        t.time_s * 1e6, t.total_j, r.time_s * 1e6, r.total_j
    );
    Ok(())
}
