# ReStream build shortcuts. The Rust crate is self-sufficient (native
# backend); only `artifacts` and the pjrt targets need Python/JAX/XLA.

ARTIFACTS ?= artifacts

.PHONY: build test bench doc fmt artifacts pytest cargotest-pjrt

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cargo fmt --all --check

# AOT-lower the JAX model graphs to HLO text (needs jax installed).
artifacts:
	cd python && python -m compile.aot --out $(abspath $(ARTIFACTS))

pytest:
	cd python && python -m pytest -q tests

# Artifact-path tests: needs the real xla crate wired in place of
# rust/vendor/xla plus an XLA extension install (see DESIGN.md).
cargotest-pjrt: artifacts
	cargo test -q --features pjrt -- --include-ignored
