# ReStream build shortcuts. The Rust crate is self-sufficient (native
# backend); only `artifacts` and the pjrt targets need Python/JAX/XLA.

ARTIFACTS ?= artifacts

.PHONY: build test bench bench-ckpt bench-cluster bench-multiapp \
	bench-parallel bench-pipeline bench-serving bench-telemetry \
	bench-train clippy doc fmt lint artifacts pytest cargotest-pjrt

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Data-parallel scaling trajectory. cargo runs bench binaries with
# cwd = rust/, so pin the report to the repo root explicitly.
bench-parallel:
	BENCH_PARALLEL_OUT=$(abspath BENCH_parallel.json) \
		cargo bench --bench perf_parallel

# Layer-pipelined streaming vs sequential/data-parallel execution.
bench-pipeline:
	BENCH_PIPELINE_OUT=$(abspath BENCH_pipeline.json) \
		cargo bench --bench perf_pipeline

# Serving throughput/latency sweep (clients x batching window).
bench-serving:
	BENCH_SERVING_OUT=$(abspath BENCH_serving.json) \
		cargo bench --bench perf_serving

# Telemetry overhead: traced vs untraced serving throughput.
bench-telemetry:
	BENCH_TELEMETRY_OUT=$(abspath BENCH_telemetry.json) \
		cargo bench --bench perf_telemetry

# Multi-tenant serving: resident-set sweep vs dedicated servers.
bench-multiapp:
	BENCH_MULTIAPP_OUT=$(abspath BENCH_multiapp.json) \
		cargo bench --bench perf_multiapp

# Data-parallel mini-batch training scaling trajectory.
bench-train:
	BENCH_TRAIN_OUT=$(abspath BENCH_train.json) \
		cargo bench --bench perf_train

# Multi-chip cluster scaling: hot app replicated across the fleet.
bench-cluster:
	BENCH_CLUSTER_OUT=$(abspath BENCH_cluster.json) \
		cargo bench --bench perf_cluster

# Checkpoint save/restore bandwidth and recovery-time objective.
bench-ckpt:
	BENCH_CKPT_OUT=$(abspath BENCH_ckpt.json) \
		cargo bench --bench perf_ckpt

clippy:
	cargo clippy --all-targets -- -D warnings

# Determinism/concurrency contract: restream-lint (rules D1-P1, see
# DESIGN.md) plus clippy. This is the same pair the CI lint job runs.
lint:
	cargo run --release -p restream-lint
	cargo clippy --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cargo fmt --all --check

# AOT-lower the JAX model graphs to HLO text (needs jax installed).
artifacts:
	cd python && python -m compile.aot --out $(abspath $(ARTIFACTS))

pytest:
	cd python && python -m pytest -q tests

# Artifact-path tests: needs the real xla crate wired in place of
# rust/vendor/xla plus an XLA extension install (see DESIGN.md).
cargotest-pjrt: artifacts
	cargo test -q --features pjrt -- --include-ignored
